//! Weak and strong rebalancing — paper Algorithm 5.
//!
//! After unconstrained label propagation the partition may violate the
//! balance constraint. Every vertex in an overloaded block proposes its
//! minimum-loss move to a neighboring block below the threshold
//! `σ < L_max` (or a random such block if none neighbors it). Proposals
//! are approximately sorted with a log₂-spaced bucket list; a per-vertex
//! decision process (bucket-local atomic weight accumulation + a prefix
//! sum over buckets) moves exactly the lightest-loss prefix needed to
//! balance the source block. *Strong* rebalancing additionally reserves
//! destination capacity atomically so destinations can never overload —
//! vertices that would overload their target are diverted to any
//! underloaded block (possibly disconnected, hence the greater loss).
//!
//! The objective used for the loss is configurable: the paper found that
//! plain edge-cut loss performs as well as `J`-loss and is cheaper — both
//! are implemented (ablation A2 in DESIGN.md).
//!
//! The `n`-sized proposal arrays live in a [`RebalanceScratch`] owned by
//! the caller's [`super::workspace::RefineWorkspace`], so repeated
//! rebalancing rounds reuse one allocation.

use super::gains::ConnTable;
use super::Objective;
use crate::graph::CsrGraph;
use crate::par::{AtomicList, Pool};
use crate::rng::hash_u64;
use crate::{Block, VWeight, Vertex};
use std::sync::atomic::{AtomicI64, AtomicU32, Ordering};

const NO_DEST: u32 = u32::MAX;
/// Number of log₂ loss buckets (plus the `+` and `0` buckets in front).
const NEG_BUCKETS: usize = 48;
const BUCKETS: usize = 2 + NEG_BUCKETS;

/// Which rebalancing flavor to run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strength {
    Weak,
    Strong,
}

/// Reusable `n`-sized scratch for [`rebalance`] (proposal destinations,
/// losses, bucket arrival weights, move list).
pub struct RebalanceScratch {
    dest: Vec<AtomicU32>,
    loss: Vec<f64>,
    my_before: Vec<VWeight>,
    moves: AtomicList,
}

impl Default for RebalanceScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl RebalanceScratch {
    pub fn new() -> Self {
        RebalanceScratch {
            dest: Vec::new(),
            loss: Vec::new(),
            my_before: Vec::new(),
            moves: AtomicList::with_capacity(0),
        }
    }

    /// Grow the buffers to cover `n` vertices.
    pub fn ensure(&mut self, n: usize) {
        if self.dest.len() < n {
            self.dest.resize_with(n, || AtomicU32::new(NO_DEST));
        }
        if self.loss.len() < n {
            self.loss.resize(n, 0.0);
        }
        if self.my_before.len() < n {
            self.my_before.resize(n, 0);
        }
        if self.moves.capacity() < n {
            self.moves = AtomicList::with_capacity(n);
        }
    }
}

/// One rebalancing step. Returns the sorted vertices to move and fills
/// `dests_out` with their destinations (aligned with the returned list).
#[allow(clippy::too_many_arguments)]
pub fn rebalance(
    pool: &Pool,
    g: &CsrGraph,
    conn: &ConnTable,
    part: &[Block],
    block_weights: &[VWeight],
    k: usize,
    l_max: VWeight,
    obj: &Objective,
    strength: Strength,
    seed: u64,
    scratch: &mut RebalanceScratch,
    dests_out: &mut Vec<Block>,
) -> Vec<Vertex> {
    let n = g.n();
    scratch.ensure(n);
    scratch.moves.reset();
    let total: VWeight = block_weights.iter().sum();
    let avg = total / k as VWeight;
    // Dead zone below L_max (paper: σ = L_max − 100 with unit weights;
    // scaled to instance size so σ stays positive on small blocks).
    let dead = ((l_max - avg).max(1) / 2).min(100);
    let sigma = l_max - dead;

    let dest = &scratch.dest;
    let loss_ptr = crate::par::SharedMut::new(&mut scratch.loss);

    // Kernel 1: per-vertex best move out of overloaded blocks (also
    // re-initializes this round's proposal slots — no separate clear pass).
    let _k1 = crate::par::ledger::kernel("refine/rebalance:propose");
    pool.parallel_for(n, |v| {
        // relaxed: dest[v] is owned by unit v within this kernel; other
        // units read it only after the barrier.
        dest[v].store(NO_DEST, Ordering::Relaxed);
        let from = part[v];
        if block_weights[from as usize] <= l_max {
            return;
        }
        // Heavy vertices may not move (paper: > 1.5·(c(Π(v)) − c(V)/k)).
        let excess = block_weights[from as usize] - avg;
        if g.vw[v] as f64 > 1.5 * excess as f64 {
            return;
        }
        let mut buf = crate::refine::ConnBuf::new();
        conn.gather_buf(v, &mut buf);
        let mut best: Option<(f64, Block)> = None;
        buf.for_each(|b, _| {
            if b == from || block_weights[b as usize] > sigma {
                return;
            }
            let gn = obj.gain_buf(&buf, from, b);
            if best.map(|(bg, bb)| gn > bg || (gn == bg && b < bb)).unwrap_or(true) {
                best = Some((gn, b));
            }
        });
        if best.is_none() {
            // Random block under the threshold (deterministic per vertex).
            let start = hash_u64(seed ^ v as u64) as usize % k;
            for i in 0..k {
                let b = ((start + i) % k) as Block;
                if b != from && block_weights[b as usize] <= sigma {
                    best = Some((obj.gain_buf(&buf, from, b), b));
                    break;
                }
            }
        }
        if let Some((gn, b)) = best {
            // relaxed: unit-owned slot, frozen by the kernel barrier.
            dest[v].store(b, Ordering::Relaxed);
            // SAFETY: each v is written by exactly one work unit.
            unsafe { loss_ptr.write(v, gn) };
        }
    });
    drop(_k1);

    let loss = &scratch.loss;

    // Kernel 2: bucket accumulation per overloaded block.
    // bucket 0 = strictly positive gain, 1 = zero gain, 2+i = loss with
    // i ≤ log2(−gain) < i+1.
    let bucket_w: Vec<AtomicI64> = (0..k * BUCKETS).map(|_| AtomicI64::new(0)).collect();
    let before_ptr = crate::par::SharedMut::new(&mut scratch.my_before);
    let _k2 = crate::par::ledger::kernel("refine/rebalance:buckets");
    pool.parallel_for(n, |v| {
        // relaxed: dest was frozen by kernel 1's barrier.
        let d = dest[v].load(Ordering::Relaxed);
        if d == NO_DEST {
            return;
        }
        let b = bucket_of(loss[v]);
        // relaxed: commutative arrival-order tally; the fetched value is
        // used only by this unit, totals are read after the barrier.
        let prev = bucket_w[part[v] as usize * BUCKETS + b].fetch_add(g.vw[v], Ordering::Relaxed);
        // SAFETY: each v is written by exactly one work unit.
        unsafe { before_ptr.write(v, prev) };
    });
    drop(_k2);

    let my_before = &scratch.my_before;

    // Prefix sums over buckets per block (k·BUCKETS is tiny: serial).
    let mut bucket_prefix = vec![0 as VWeight; k * BUCKETS];
    for blk in 0..k {
        let mut acc = 0;
        for b in 0..BUCKETS {
            bucket_prefix[blk * BUCKETS + b] = acc;
            // relaxed: host-side read after kernel 2's barrier.
            acc += bucket_w[blk * BUCKETS + b].load(Ordering::Relaxed);
        }
    }

    // Kernel 3: per-vertex decision — move iff the weight moved before me
    // (earlier buckets + earlier arrivals in my bucket) is below the
    // block's excess.
    let moves = &scratch.moves;
    // Strong: atomic destination reservations.
    let reserved: Vec<AtomicI64> =
        (0..k).map(|b| AtomicI64::new(block_weights[b].min(l_max))).collect();
    let _k3 = crate::par::ledger::kernel("refine/rebalance:decide");
    pool.parallel_for(n, |v| {
        // relaxed: dest was frozen before this kernel; only unit v itself
        // may overwrite its slot below (divert case).
        let d = dest[v].load(Ordering::Relaxed);
        if d == NO_DEST {
            return;
        }
        let from = part[v] as usize;
        let excess = block_weights[from] - l_max;
        let b = bucket_of(loss[v]);
        let before = bucket_prefix[from * BUCKETS + b] + my_before[v];
        if before >= excess {
            return; // enough weight already scheduled to leave
        }
        match strength {
            Strength::Weak => {
                moves.push(v as u64);
            }
            Strength::Strong => {
                // Reserve capacity at the destination; divert if full.
                // relaxed: fetch_add/fetch_sub reservations are a pure
                // commutative counter protocol — the RMW itself is the
                // claim, no other data is published through it.
                let mut target = d;
                let got = reserved[target as usize].fetch_add(g.vw[v], Ordering::Relaxed);
                if got + g.vw[v] > l_max {
                    reserved[target as usize].fetch_sub(g.vw[v], Ordering::Relaxed);
                    // Divert to any block with room (deterministic probe).
                    let start = hash_u64(seed ^ (v as u64) << 1) as usize % k;
                    let mut found = false;
                    for i in 0..k {
                        let cand = ((start + i) % k) as Block;
                        if cand as usize == from {
                            continue;
                        }
                        // relaxed: same commutative reservation protocol.
                        let r = reserved[cand as usize].fetch_add(g.vw[v], Ordering::Relaxed);
                        if r + g.vw[v] <= l_max {
                            target = cand;
                            found = true;
                            break;
                        }
                        reserved[cand as usize].fetch_sub(g.vw[v], Ordering::Relaxed);
                    }
                    if !found {
                        return; // nowhere to go; stay
                    }
                    // relaxed: unit v overwrites its own slot; read
                    // host-side after the barrier.
                    dest[v].store(target, Ordering::Relaxed);
                }
                moves.push(v as u64);
            }
        }
    });

    let mut move_list: Vec<Vertex> =
        (0..moves.len()).map(|i| moves.get(i) as Vertex).collect();
    move_list.sort_unstable();
    dests_out.clear();
    // relaxed: host-side read after kernel 3's barrier.
    dests_out.extend(move_list.iter().map(|&v| dest[v as usize].load(Ordering::Relaxed)));
    move_list
}

/// Bucket index: 0 = positive, 1 = zero, 2+⌊log₂(−gain)⌋ for losses.
#[inline]
fn bucket_of(gain: f64) -> usize {
    if gain > 0.0 {
        0
    } else if gain == 0.0 {
        1
    } else {
        let l = (-gain).log2().floor();
        2 + (l.max(0.0) as usize).min(NEG_BUCKETS - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, EdgeList};
    use crate::partition::{block_weights as bw_of, l_max as lmax_of, max_block_weight};
    use crate::rng::Rng;
    use crate::topology::Machine;

    fn overload_partition(g: &CsrGraph, k: usize) -> Vec<Block> {
        // 70% of vertices in block 0, rest spread.
        let mut rng = Rng::new(11);
        (0..g.n())
            .map(|_| {
                if rng.f64() < 0.7 {
                    0
                } else {
                    rng.below(k as u64) as Block
                }
            })
            .collect()
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 576-vertex grid rebalance rounds, too slow
    fn weak_rebalance_reduces_overload() {
        let g = gen::grid2d(24, 24, false);
        let k = 8;
        let h = Machine::hier("4:2", "1:10").unwrap();
        let mut part = overload_partition(&g, k);
        let lmax = lmax_of(g.total_vweight(), k, 0.03);
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut scratch = RebalanceScratch::new();
        let mut dests = Vec::new();
        let before_max = max_block_weight(&g, &part, k);
        for _ in 0..6 {
            let bw = bw_of(&g, &part, k);
            if bw.iter().max().copied().unwrap() <= lmax {
                break;
            }
            let conn = ConnTable::build(&pool, &g, &el, &part, k);
            let moves = rebalance(
                &pool, &g, &conn, &part, &bw, k, lmax, &Objective::Comm(&h), Strength::Weak, 3,
                &mut scratch, &mut dests,
            );
            assert!(!moves.is_empty(), "weak rebalance made no progress");
            for (i, &v) in moves.iter().enumerate() {
                part[v as usize] = dests[i];
            }
        }
        let after_max = max_block_weight(&g, &part, k);
        assert!(after_max < before_max, "{before_max} -> {after_max}");
        assert!(after_max <= lmax + lmax / 4, "still badly overloaded: {after_max} vs {lmax}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 2000-vertex rgg, too slow
    fn strong_rebalance_balances_in_one_step() {
        let g = gen::rgg(2_000, 0.05, 13);
        let k = 16;
        let mut part = overload_partition(&g, k);
        let lmax = lmax_of(g.total_vweight(), k, 0.10);
        let el = EdgeList::build(&g);
        let pool = Pool::new(2);
        let bw = bw_of(&g, &part, k);
        let conn = ConnTable::build(&pool, &g, &el, &part, k);
        let mut scratch = RebalanceScratch::new();
        let mut dests = Vec::new();
        let moves = rebalance(
            &pool, &g, &conn, &part, &bw, k, lmax, &Objective::Cut, Strength::Strong, 5,
            &mut scratch, &mut dests,
        );
        for (i, &v) in moves.iter().enumerate() {
            part[v as usize] = dests[i];
        }
        let after = bw_of(&g, &part, k);
        // Strong rebalancing must not overload any *destination*: every
        // block that was under L_max stays under L_max.
        for b in 0..k {
            if bw[b] <= lmax {
                assert!(after[b] <= lmax, "block {b} overloaded by strong rebalance");
            }
        }
        // And the overloaded block must have shed weight.
        assert!(after[0] < bw[0]);
    }

    #[test]
    fn bucket_of_spacing() {
        assert_eq!(bucket_of(5.0), 0);
        assert_eq!(bucket_of(0.0), 1);
        assert_eq!(bucket_of(-1.0), 2);
        assert_eq!(bucket_of(-2.0), 3);
        assert_eq!(bucket_of(-3.9), 3);
        assert_eq!(bucket_of(-4.0), 4);
        assert!(bucket_of(-1e30) < BUCKETS);
    }

    #[test]
    fn balanced_input_is_noop() {
        let g = gen::grid2d(10, 10, false);
        let k = 4;
        let part: Vec<Block> = (0..g.n()).map(|v| (v % k) as Block).collect();
        let lmax = lmax_of(g.total_vweight(), k, 0.10);
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let bw = bw_of(&g, &part, k);
        let conn = ConnTable::build(&pool, &g, &el, &part, k);
        let h = Machine::hier("2:2", "1:10").unwrap();
        let mut scratch = RebalanceScratch::new();
        let mut dests = Vec::new();
        let moves = rebalance(
            &pool, &g, &conn, &part, &bw, k, lmax, &Objective::Comm(&h), Strength::Weak, 1,
            &mut scratch, &mut dests,
        );
        assert!(moves.is_empty());
        assert!(dests.is_empty());
    }

    #[test]
    fn heavy_vertices_stay() {
        let mut g = gen::grid2d(8, 8, false);
        // Vertex 0 carries most of its block's excess: the paper's rule
        // `c(v) > 1.5·(c(Π(v)) − c(V)/k)` must exclude it from moving.
        g.vw[0] = 30;
        let k = 4;
        let part: Vec<Block> =
            (0..g.n()).map(|v| if v < 10 { 0 } else { (v % 3 + 1) as Block }).collect();
        let lmax = lmax_of(g.total_vweight(), k, 0.05);
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let bw = bw_of(&g, &part, k);
        let conn = ConnTable::build(&pool, &g, &el, &part, k);
        let mut scratch = RebalanceScratch::new();
        let mut dests = Vec::new();
        let moves = rebalance(
            &pool, &g, &conn, &part, &bw, k, lmax, &Objective::Cut, Strength::Weak, 2,
            &mut scratch, &mut dests,
        );
        assert!(!moves.contains(&0), "heavy vertex moved");
    }

    #[test]
    fn scratch_reuse_across_rounds_is_clean() {
        // Two different overload patterns through the same scratch: stale
        // proposals from round 1 must not leak into round 2.
        let g = gen::grid2d(16, 16, false);
        let k = 4;
        let lmax = lmax_of(g.total_vweight(), k, 0.05);
        let el = EdgeList::build(&g);
        let pool = Pool::new(2);
        let h = Machine::hier("2:2", "1:10").unwrap();
        let mut scratch = RebalanceScratch::new();
        let mut dests = Vec::new();
        // Round 1: overloaded.
        let part1 = overload_partition(&g, k);
        let bw1 = bw_of(&g, &part1, k);
        let conn1 = ConnTable::build(&pool, &g, &el, &part1, k);
        let moves1 = rebalance(
            &pool, &g, &conn1, &part1, &bw1, k, lmax, &Objective::Comm(&h), Strength::Weak, 7,
            &mut scratch, &mut dests,
        );
        assert!(!moves1.is_empty());
        // Round 2: perfectly balanced — must be a no-op despite the dirty
        // scratch.
        let part2: Vec<Block> = (0..g.n()).map(|v| (v % k) as Block).collect();
        let bw2 = bw_of(&g, &part2, k);
        let conn2 = ConnTable::build(&pool, &g, &el, &part2, k);
        let moves2 = rebalance(
            &pool, &g, &conn2, &part2, &bw2, k, lmax, &Objective::Comm(&h), Strength::Weak, 7,
            &mut scratch, &mut dests,
        );
        assert!(moves2.is_empty());
    }
}
