//! Refinement: local search minimizing edge-cut or the mapping objective
//! `J(C, D, Π)`.
//!
//! Serial algorithms (2-way FM, k-way label propagation) power the CPU
//! baselines and the initial-partitioning substrate. The device-style
//! algorithms are the paper's contribution: unconstrained label
//! propagation (Alg. 4, [`jet_lp`]), weak/strong rebalancing (Alg. 5,
//! [`rebalance`]) and the refinement controller (Alg. 6, [`jet_loop`]),
//! all built on the per-vertex block-connectivity structure ([`gains`]).

pub mod fm2;
pub mod gains;
pub mod jet_loop;
pub mod jet_lp;
pub mod lp_serial;
pub mod rebalance;
pub mod workspace;

pub use gains::ConnUpdate;
pub use workspace::RefineWorkspace;

use crate::topology::{DistanceMatrix, Hierarchy};
use crate::Block;

/// The objective a refinement pass minimizes.
#[derive(Clone, Copy)]
pub enum Objective<'a> {
    /// Edge-cut (graph partitioning; distance vector `1:…:1`).
    Cut,
    /// Communication cost `J(C, D, Π)` under a hierarchy (process
    /// mapping), using the implicit O(ℓ) distance oracle.
    Comm(&'a Hierarchy),
    /// Communication cost with the materialized `k × k` distance matrix —
    /// the paper's O(k²)-space / O(1)-lookup representation, used on the
    /// device refinement hot path (§Perf opt 1).
    CommMat(&'a DistanceMatrix),
}

impl<'a> Objective<'a> {
    /// Gain of moving a vertex from `from` to `to`, given its block
    /// connectivities `conn = [(block, Σ edge weight to block)]`
    /// (paper Eq. 1):
    ///
    /// * cut: `conn(to) − conn(from)`
    /// * comm: `Σ_b conn(b)·(D[from,b] − D[to,b])`
    pub fn gain(&self, conn: &[(Block, f64)], from: Block, to: Block) -> f64 {
        match self {
            Objective::Cut => {
                let mut cf = 0.0;
                let mut ct = 0.0;
                for &(b, w) in conn {
                    if b == from {
                        cf = w;
                    } else if b == to {
                        ct = w;
                    }
                }
                ct - cf
            }
            Objective::Comm(h) => {
                let mut g = 0.0;
                for &(b, w) in conn {
                    g += w * (h.distance(from, b) - h.distance(to, b));
                }
                g
            }
            Objective::CommMat(m) => {
                let rf = m.row(from);
                let rt = m.row(to);
                let mut g = 0.0;
                for &(b, w) in conn {
                    g += w * (rf[b as usize] - rt[b as usize]);
                }
                g
            }
        }
    }

    /// Materialize the hot-path form: `Comm` becomes `CommMat`.
    pub fn materialize(&self) -> Option<DistanceMatrix> {
        match self {
            Objective::Comm(h) => Some(h.distance_matrix()),
            _ => None,
        }
    }
}

/// Allocation-free block-connectivity buffer for the per-vertex gain
/// kernels (§Perf opt 2): up to `STACK` entries live on the stack; the
/// rare high-degree coarse vertex spills to the heap.
pub struct ConnBuf {
    stack: [(Block, f64); ConnBuf::STACK],
    len: usize,
    spill: Vec<(Block, f64)>,
}

impl Default for ConnBuf {
    fn default() -> Self {
        Self::new()
    }
}

impl ConnBuf {
    pub const STACK: usize = 96;

    #[inline]
    pub fn new() -> Self {
        ConnBuf { stack: [(0, 0.0); Self::STACK], len: 0, spill: Vec::new() }
    }

    #[inline]
    pub fn clear(&mut self) {
        self.len = 0;
        self.spill.clear();
    }

    #[inline]
    pub fn push(&mut self, b: Block, w: f64) {
        if self.len < Self::STACK {
            self.stack[self.len] = (b, w);
            self.len += 1;
        } else {
            self.spill.push((b, w));
        }
    }

    /// Insert-or-accumulate by linear scan (conn lists are short).
    #[inline]
    pub fn add(&mut self, b: Block, w: f64) {
        for e in self.stack[..self.len].iter_mut() {
            if e.0 == b {
                e.1 += w;
                return;
            }
        }
        for e in self.spill.iter_mut() {
            if e.0 == b {
                e.1 += w;
                return;
            }
        }
        self.push(b, w);
    }

    /// Entries as a slice when no spill occurred; falls back to a unified
    /// iteration otherwise.
    #[inline]
    pub fn for_each(&self, mut f: impl FnMut(Block, f64)) {
        for &(b, w) in &self.stack[..self.len] {
            f(b, w);
        }
        for &(b, w) in &self.spill {
            f(b, w);
        }
    }

    #[inline]
    pub fn slice(&self) -> &[(Block, f64)] {
        debug_assert!(self.spill.is_empty() || self.len < Self::STACK);
        &self.stack[..self.len]
    }

    #[inline]
    pub fn has_spill(&self) -> bool {
        !self.spill.is_empty()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0 && self.spill.is_empty()
    }
}

impl<'a> Objective<'a> {
    /// [`Objective::gain`] over a [`ConnBuf`] (handles spill).
    pub fn gain_buf(&self, conn: &ConnBuf, from: Block, to: Block) -> f64 {
        if !conn.has_spill() {
            return self.gain(conn.slice(), from, to);
        }
        match self {
            Objective::Cut => {
                let mut cf = 0.0;
                let mut ct = 0.0;
                conn.for_each(|b, w| {
                    if b == from {
                        cf = w;
                    } else if b == to {
                        ct = w;
                    }
                });
                ct - cf
            }
            Objective::Comm(h) => {
                let mut g = 0.0;
                conn.for_each(|b, w| g += w * (h.distance(from, b) - h.distance(to, b)));
                g
            }
            Objective::CommMat(m) => {
                let rf = m.row(from);
                let rt = m.row(to);
                let mut g = 0.0;
                conn.for_each(|b, w| g += w * (rf[b as usize] - rt[b as usize]));
                g
            }
        }
    }
}

/// Total-order wrapper for `f64` priorities in heaps.
#[derive(Clone, Copy, PartialEq)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cut_gain_from_conn() {
        let conn = vec![(0u32, 3.0), (1u32, 5.0)];
        assert_eq!(Objective::Cut.gain(&conn, 0, 1), 2.0);
        assert_eq!(Objective::Cut.gain(&conn, 1, 0), -2.0);
    }

    #[test]
    fn comm_gain_matches_eq1() {
        let h = Hierarchy::parse("2:2", "1:10").unwrap();
        // Vertex in PE 0, neighbors: 2.0 to PE 0, 1.0 to PE 2.
        let conn = vec![(0u32, 2.0), (2u32, 1.0)];
        // Move 0 → 1: Σ conn(b)·(D[0,b] − D[1,b])
        //  = 2·(0 − 1) + 1·(10 − 10) = −2.
        let g = Objective::Comm(&h).gain(&conn, 0, 1);
        assert!((g - (-2.0)).abs() < 1e-12);
        // Move 0 → 2: 2·(0 − 10) + 1·(10 − 0) = −10.
        let g2 = Objective::Comm(&h).gain(&conn, 0, 2);
        assert!((g2 - (-10.0)).abs() < 1e-12);
    }

    #[test]
    fn comm_gain_positive_when_moving_toward_neighbors() {
        let h = Hierarchy::parse("2:2", "1:10").unwrap();
        // Vertex on PE 3, all neighbors on PE 0.
        let conn = vec![(0u32, 4.0)];
        // Moving to PE 1 (same node as 0): 4·(D[3,0] − D[1,0]) = 4·(10−1) = 36.
        let g = Objective::Comm(&h).gain(&conn, 3, 1);
        assert!((g - 36.0).abs() < 1e-12);
    }

    #[test]
    fn ordf64_total_order() {
        let mut v = vec![OrdF64(2.0), OrdF64(-1.0), OrdF64(0.5)];
        v.sort();
        assert_eq!(v[0].0, -1.0);
        assert_eq!(v[2].0, 2.0);
    }
}
