//! Refinement: local search minimizing edge-cut or the mapping objective
//! `J(C, D, Π)`.
//!
//! Serial algorithms (2-way FM, k-way label propagation) power the CPU
//! baselines and the initial-partitioning substrate. The device-style
//! algorithms are the paper's contribution: unconstrained label
//! propagation (Alg. 4, [`jet_lp`]), weak/strong rebalancing (Alg. 5,
//! [`rebalance`]) and the refinement controller (Alg. 6, [`jet_loop`]),
//! all built on the per-vertex block-connectivity structure ([`gains`]).

pub mod fm2;
pub mod gains;
pub mod jet_loop;
pub mod jet_lp;
pub mod lp_serial;
pub mod rebalance;
pub mod workspace;

pub use gains::ConnUpdate;
pub use workspace::RefineWorkspace;

use crate::topology::{DistanceOracle, Machine};
use crate::Block;

/// The objective a refinement pass minimizes.
#[derive(Clone, Copy)]
pub enum Objective<'a> {
    /// Edge-cut (graph partitioning; distance vector `1:…:1`).
    Cut,
    /// Communication cost `J(C, D, Π)` under a machine model (process
    /// mapping), every distance answered by the model's implicit oracle.
    Comm(&'a Machine),
    /// Communication cost through a prebuilt [`DistanceOracle`] — dense
    /// rows (O(1) lookups) for `k ≤ DENSE_K_MAX`, the implicit model
    /// beyond that, so the hot path never materializes O(k²) on big
    /// machines (§Perf opt 1).
    Oracle(&'a DistanceOracle),
}

impl<'a> Objective<'a> {
    /// Gain of moving a vertex from `from` to `to`, given its block
    /// connectivities `conn = [(block, Σ edge weight to block)]`
    /// (paper Eq. 1):
    ///
    /// * cut: `conn(to) − conn(from)`
    /// * comm: `Σ_b conn(b)·(D[from,b] − D[to,b])`
    pub fn gain(&self, conn: &[(Block, f64)], from: Block, to: Block) -> f64 {
        match self {
            Objective::Cut => {
                let mut cf = 0.0;
                let mut ct = 0.0;
                for &(b, w) in conn {
                    if b == from {
                        cf = w;
                    } else if b == to {
                        ct = w;
                    }
                }
                ct - cf
            }
            Objective::Comm(m) => {
                let mut g = 0.0;
                for &(b, w) in conn {
                    g += w * (m.distance(from, b) - m.distance(to, b));
                }
                g
            }
            Objective::Oracle(o) => o.gain(conn, from, to),
        }
    }

    /// The hot-path form: `Comm` becomes `Oracle` with the
    /// refinement-flavor backend ([`DistanceOracle::for_refine`] — dense
    /// for small machines, implicit beyond `DENSE_K_MAX`).
    pub fn upgraded(&self) -> Option<DistanceOracle> {
        match self {
            Objective::Comm(m) => Some(DistanceOracle::for_refine(m)),
            _ => None,
        }
    }
}

/// Allocation-free block-connectivity buffer for the per-vertex gain
/// kernels (§Perf opt 2): up to `STACK` entries live on the stack; the
/// rare high-degree coarse vertex spills to the heap.
pub struct ConnBuf {
    stack: [(Block, f64); ConnBuf::STACK],
    len: usize,
    spill: Vec<(Block, f64)>,
}

impl Default for ConnBuf {
    fn default() -> Self {
        Self::new()
    }
}

impl ConnBuf {
    pub const STACK: usize = 96;

    #[inline]
    pub fn new() -> Self {
        ConnBuf { stack: [(0, 0.0); Self::STACK], len: 0, spill: Vec::new() }
    }

    #[inline]
    pub fn clear(&mut self) {
        self.len = 0;
        self.spill.clear();
    }

    #[inline]
    pub fn push(&mut self, b: Block, w: f64) {
        if self.len < Self::STACK {
            self.stack[self.len] = (b, w);
            self.len += 1;
        } else {
            self.spill.push((b, w));
        }
    }

    /// Insert-or-accumulate by linear scan (conn lists are short).
    #[inline]
    pub fn add(&mut self, b: Block, w: f64) {
        for e in self.stack[..self.len].iter_mut() {
            if e.0 == b {
                e.1 += w;
                return;
            }
        }
        for e in self.spill.iter_mut() {
            if e.0 == b {
                e.1 += w;
                return;
            }
        }
        self.push(b, w);
    }

    /// Entries as a slice when no spill occurred; falls back to a unified
    /// iteration otherwise.
    #[inline]
    pub fn for_each(&self, mut f: impl FnMut(Block, f64)) {
        for &(b, w) in &self.stack[..self.len] {
            f(b, w);
        }
        for &(b, w) in &self.spill {
            f(b, w);
        }
    }

    #[inline]
    pub fn slice(&self) -> &[(Block, f64)] {
        debug_assert!(self.spill.is_empty() || self.len < Self::STACK);
        &self.stack[..self.len]
    }

    #[inline]
    pub fn has_spill(&self) -> bool {
        !self.spill.is_empty()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0 && self.spill.is_empty()
    }
}

impl<'a> Objective<'a> {
    /// [`Objective::gain`] over a [`ConnBuf`] (handles spill).
    pub fn gain_buf(&self, conn: &ConnBuf, from: Block, to: Block) -> f64 {
        if !conn.has_spill() {
            return self.gain(conn.slice(), from, to);
        }
        match self {
            Objective::Cut => {
                let mut cf = 0.0;
                let mut ct = 0.0;
                conn.for_each(|b, w| {
                    if b == from {
                        cf = w;
                    } else if b == to {
                        ct = w;
                    }
                });
                ct - cf
            }
            Objective::Comm(m) => {
                let mut g = 0.0;
                conn.for_each(|b, w| g += w * (m.distance(from, b) - m.distance(to, b)));
                g
            }
            Objective::Oracle(o) => {
                if let Some((rf, rt)) = o.dense_rows(from, to) {
                    let mut g = 0.0;
                    conn.for_each(|b, w| g += w * (rf[b as usize] - rt[b as usize]));
                    g
                } else {
                    let rf = o.row(from);
                    let rt = o.row(to);
                    let mut g = 0.0;
                    conn.for_each(|b, w| g += w * (rf.get(b) - rt.get(b)));
                    g
                }
            }
        }
    }
}

/// Total-order wrapper for `f64` priorities in heaps.
#[derive(Clone, Copy, PartialEq)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cut_gain_from_conn() {
        let conn = vec![(0u32, 3.0), (1u32, 5.0)];
        assert_eq!(Objective::Cut.gain(&conn, 0, 1), 2.0);
        assert_eq!(Objective::Cut.gain(&conn, 1, 0), -2.0);
    }

    #[test]
    fn comm_gain_matches_eq1() {
        let h = Machine::hier("2:2", "1:10").unwrap();
        // Vertex in PE 0, neighbors: 2.0 to PE 0, 1.0 to PE 2.
        let conn = vec![(0u32, 2.0), (2u32, 1.0)];
        // Move 0 → 1: Σ conn(b)·(D[0,b] − D[1,b])
        //  = 2·(0 − 1) + 1·(10 − 10) = −2.
        let g = Objective::Comm(&h).gain(&conn, 0, 1);
        assert!((g - (-2.0)).abs() < 1e-12);
        // Move 0 → 2: 2·(0 − 10) + 1·(10 − 0) = −10.
        let g2 = Objective::Comm(&h).gain(&conn, 0, 2);
        assert!((g2 - (-10.0)).abs() < 1e-12);
    }

    #[test]
    fn comm_gain_positive_when_moving_toward_neighbors() {
        let h = Machine::hier("2:2", "1:10").unwrap();
        // Vertex on PE 3, all neighbors on PE 0.
        let conn = vec![(0u32, 4.0)];
        // Moving to PE 1 (same node as 0): 4·(D[3,0] − D[1,0]) = 4·(10−1) = 36.
        let g = Objective::Comm(&h).gain(&conn, 3, 1);
        assert!((g - 36.0).abs() < 1e-12);
    }

    #[test]
    fn oracle_objective_matches_implicit_for_every_backend() {
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let conn = vec![(0u32, 2.0), (3u32, 1.0), (6u32, 0.5)];
        let want = Objective::Comm(&h).gain(&conn, 1, 4);
        for oracle in [
            DistanceOracle::implicit(&h),
            DistanceOracle::dense(&h),
            DistanceOracle::blocked(&h, 2),
        ] {
            let got = Objective::Oracle(&oracle).gain(&conn, 1, 4);
            assert!((got - want).abs() < 1e-12, "{}", oracle.backend_name());
        }
        // upgraded(): small machine → dense rows.
        let up = Objective::Comm(&h).upgraded().unwrap();
        assert_eq!(up.backend_name(), "dense");
        assert!(Objective::Cut.upgraded().is_none());
    }

    #[test]
    fn ordf64_total_order() {
        let mut v = vec![OrdF64(2.0), OrdF64(-1.0), OrdF64(0.5)];
        v.sort();
        assert_eq!(v[0].0, -1.0);
        assert_eq!(v[2].0, 2.0);
    }
}
