//! The refinement controller — paper Algorithm 6.
//!
//! Alternates unconstrained label propagation (balanced state) with weak /
//! strong rebalancing (unbalanced state; at most two consecutive weak
//! steps before a strong one), keeping the best feasible mapping found.
//! The iteration counter resets whenever the objective improves by more
//! than the factor `φ = 0.999` (or balance improves while infeasible), and
//! the loop ends after `iter_limit` (12; 18 for the *ultra* flavor)
//! iterations without significant progress.
//!
//! **Hot-path structure** (§Perf): the controller objective is maintained
//! *incrementally* — every move round adds an edge-parallel ΔJ reduction
//! over just the moved vertices' incident edges instead of re-reducing all
//! `2m` edges, with a periodic exact re-reduction
//! ([`JetConfig::resync_every`]) bounding FP drift. Moves are applied by a
//! parallel kernel (old-block recording, block-weight atomics), and the
//! connectivity table is updated with either of the paper's two §4.2
//! strategies ([`ConnUpdate`]). All scratch lives in a caller-provided
//! [`RefineWorkspace`] ([`jet_refine_with`]), which multilevel pipelines
//! allocate once and reuse across levels.

use super::gains::{ConnTable, ConnUpdate};
use super::jet_lp::Filter;
use super::rebalance::{rebalance, Strength};
use super::workspace::RefineWorkspace;
use super::Objective;
use crate::cancel::CancelToken;
use crate::graph::{CsrGraph, EdgeList};
use crate::par::{Pool, SharedMut};
use crate::partition::block_weights;
use crate::{Block, VWeight, Vertex};
use std::sync::atomic::{AtomicI64, Ordering};

/// Controller configuration (constants transferred from Jet).
#[derive(Clone, Debug)]
pub struct JetConfig {
    /// Iterations without significant improvement before stopping (12).
    pub iter_limit: usize,
    /// Consecutive weak rebalances before a strong one (2).
    pub weak_limit: usize,
    /// Significant-improvement factor φ (0.999).
    pub phi: f64,
    /// First-filter flavor for LP.
    pub filter: Filter,
    /// Use the mapping objective `J` for the rebalancing loss too
    /// (ablation A2; the paper ships with edge-cut loss: `false`).
    pub rebalance_with_comm_obj: bool,
    /// Seed for the deterministic random choices in rebalancing.
    pub seed: u64,
    /// Conn-table update strategy after each move kernel (paper §4.2).
    pub conn_update: ConnUpdate,
    /// Exact objective re-reduction every this many move rounds, bounding
    /// FP drift of the incremental tracker (1 = re-reduce every round,
    /// i.e. the pre-incremental behavior).
    pub resync_every: usize,
    /// Cooperative cancellation, polled at the top of every controller
    /// round: a tripped token ends the run after the current round, and
    /// the best mapping found so far is still written back.
    pub cancel: CancelToken,
}

impl Default for JetConfig {
    fn default() -> Self {
        JetConfig {
            iter_limit: 12,
            weak_limit: 2,
            phi: 0.999,
            filter: Filter::NonNegative,
            rebalance_with_comm_obj: false,
            seed: 0,
            conn_update: ConnUpdate::Auto,
            resync_every: 32,
            cancel: CancelToken::default(),
        }
    }
}

impl JetConfig {
    /// The *ultra* flavor: 18 refinement iterations.
    pub fn ultra(mut self) -> Self {
        self.iter_limit = 18;
        self
    }
}

/// Statistics of one controller run.
#[derive(Clone, Copy, Debug, Default)]
pub struct RefineStats {
    pub iterations: usize,
    pub lp_steps: usize,
    pub weak_steps: usize,
    pub strong_steps: usize,
    pub moves: usize,
    /// Move rounds whose conn table was updated with the delta strategy.
    pub conn_delta_rounds: usize,
    /// Move rounds whose conn table was updated with the refill strategy.
    pub conn_refill_rounds: usize,
    /// Exact objective re-reductions triggered by `resync_every`.
    pub objective_resyncs: usize,
    /// Objective of the returned mapping (always an exact reduction).
    pub final_objective: f64,
}

/// Evaluate the controller objective with an edge-parallel reduction.
fn eval_objective(pool: &Pool, g: &CsrGraph, el: &EdgeList, part: &[Block], obj: &Objective) -> f64 {
    let _k = crate::par::ledger::kernel("refine/jet_loop:objective");
    match obj {
        Objective::Cut => {
            pool.reduce_sum_f64(g.num_directed(), |i| {
                let u = el.eu[i] as usize;
                let v = g.adj[i] as usize;
                if part[u] != part[v] {
                    g.ew[i]
                } else {
                    0.0
                }
            }) / 2.0
        }
        Objective::Comm(m) => crate::partition::comm_cost_par(pool, g, &el.eu, part, m),
        Objective::Oracle(o) => pool.reduce_sum_f64(g.num_directed(), |i| {
            let u = el.eu[i] as usize;
            let v = g.adj[i] as usize;
            g.ew[i] * o.get(part[u], part[v])
        }),
    }
}

/// Cost contribution of one directed edge slot between blocks `a` and `b`
/// (before multiplying by the edge weight).
#[inline]
fn pair_cost(obj: &Objective, a: Block, b: Block) -> f64 {
    match obj {
        Objective::Cut => {
            if a == b {
                0.0
            } else {
                1.0
            }
        }
        Objective::Comm(m) => m.distance(a, b),
        Objective::Oracle(o) => o.get(a, b),
    }
}

/// [`eval_objective`] halves the directed edge-cut sum; the communication
/// objectives count every directed slot.
#[inline]
fn directed_scale(obj: &Objective) -> f64 {
    match obj {
        Objective::Cut => 0.5,
        _ => 1.0,
    }
}

#[inline]
fn max_bw(bw: &[AtomicI64], k: usize) -> VWeight {
    // relaxed: host-side read between kernels; the move kernel's barrier
    // has already published every weight update.
    bw[..k].iter().map(|w| w.load(Ordering::Relaxed)).max().unwrap_or(0)
}

/// Run Algorithm 6 on `part` in place with a fresh workspace. Returns run
/// statistics. Multilevel callers should prefer [`jet_refine_with`] and
/// reuse one [`RefineWorkspace`] across levels.
#[allow(clippy::too_many_arguments)]
pub fn jet_refine(
    pool: &Pool,
    g: &CsrGraph,
    el: &EdgeList,
    part: &mut Vec<Block>,
    k: usize,
    l_max: VWeight,
    obj: &Objective,
    cfg: &JetConfig,
) -> RefineStats {
    let mut ws = RefineWorkspace::new();
    jet_refine_with(pool, g, el, part, k, l_max, obj, cfg, &mut ws)
}

/// Run Algorithm 6 on `part` in place, using (and growing) the caller's
/// workspace. Returns run statistics.
#[allow(clippy::too_many_arguments)]
pub fn jet_refine_with(
    pool: &Pool,
    g: &CsrGraph,
    el: &EdgeList,
    part: &mut Vec<Block>,
    k: usize,
    l_max: VWeight,
    obj: &Objective,
    cfg: &JetConfig,
    ws: &mut RefineWorkspace,
) -> RefineStats {
    let n = g.n();
    let mut stats = RefineStats::default();
    if n == 0 || k <= 1 {
        stats.final_objective = eval_objective(pool, g, el, part, obj);
        return stats;
    }
    // §Perf opt 1: build the refinement-flavor distance oracle once per
    // call — dense rows (O(1) lookups) for machines up to DENSE_K_MAX,
    // the implicit model oracle beyond that, so big machines never pay
    // an O(k²) materialization.
    let oracle = obj.upgraded();
    let obj: &Objective = &match &oracle {
        Some(o) => Objective::Oracle(o),
        None => *obj,
    };

    ws.ensure(n, k);
    ws.lp.new_pass();

    let mut cur = part.clone();
    for (b, w) in block_weights(g, &cur, k).into_iter().enumerate() {
        // relaxed: host-side seeding before any kernel runs.
        ws.bw[b].store(w, Ordering::Relaxed);
    }
    let conn = ConnTable::build(pool, g, el, &cur, k);

    // §Perf opt: the controller objective is tracked incrementally from
    // per-move ΔJ reductions; exact reductions run once here, every
    // `resync_every` move rounds, and once at the end.
    let mut j_cur = eval_objective(pool, g, el, &cur, obj);
    let mut rounds_since_sync = 0usize;

    // Best (returned) mapping state.
    let mut best = cur.clone();
    let mut best_balanced = max_bw(&ws.bw, k) <= l_max;
    let mut best_j = j_cur;
    let mut best_imb = max_bw(&ws.bw, k);

    let mut i = 0usize;
    let mut i_w = 0usize;
    let mut empty_rounds = 0usize;
    let reb_obj_comm = cfg.rebalance_with_comm_obj;

    // Per-iteration buffers, reused across rounds.
    let mut dests: Vec<Block> = Vec::new();
    let mut affected: Vec<Vertex> = Vec::new();
    let mut bw_snapshot: Vec<VWeight> = Vec::new();

    while i < cfg.iter_limit {
        // Jet-round cancellation boundary: leave with the best (valid)
        // mapping found so far rather than finishing the schedule.
        if cfg.cancel.is_cancelled() {
            break;
        }
        i += 1;
        stats.iterations += 1;

        let moves: Vec<Vertex> = if max_bw(&ws.bw, k) <= l_max {
            stats.lp_steps += 1;
            i_w = 0;
            let m = ws.lp.run(pool, g, &conn, &cur, obj, cfg.filter);
            dests.clear();
            dests.extend(m.iter().map(|&v| ws.lp.dest_of(v)));
            m
        } else {
            let strength = if i_w < cfg.weak_limit {
                i_w += 1;
                stats.weak_steps += 1;
                Strength::Weak
            } else {
                i_w = 0;
                stats.strong_steps += 1;
                Strength::Strong
            };
            let reb_obj = if reb_obj_comm { *obj } else { Objective::Cut };
            ws.bw_snapshot(k, &mut bw_snapshot);
            rebalance(
                pool,
                g,
                &conn,
                &cur,
                &bw_snapshot,
                k,
                l_max,
                &reb_obj,
                strength,
                cfg.seed ^ (i as u64) << 8,
                &mut ws.reb,
                &mut dests,
            )
        };

        stats.moves += moves.len();
        if !moves.is_empty() {
            // Move(M, Π''): the former serial apply loop as a parallel
            // kernel — records old blocks, flips assignments, and updates
            // block weights atomically.
            let epoch = ws.moved_marks.begin(n);
            {
                let marks = &ws.moved_marks;
                let bw = &ws.bw;
                let cur_ptr = SharedMut::new(&mut cur);
                let old_ptr = SharedMut::new(&mut ws.old_block);
                let moves_r = &moves;
                let dests_r = &dests;
                let _k = crate::par::ledger::kernel("refine/jet_loop:apply_moves");
                pool.parallel_for(moves_r.len(), |idx| {
                    let v = moves_r[idx] as usize;
                    let to = dests_r[idx];
                    // SAFETY: a move list names each vertex at most once,
                    // so slot v is owned by exactly this work unit.
                    let from = unsafe { cur_ptr.read(v) };
                    unsafe { old_ptr.write(v, from) };
                    unsafe { cur_ptr.write(v, to) };
                    marks.mark(v, epoch);
                    // relaxed: commutative weight tallies, read after the
                    // barrier (see max_bw / bw_snapshot).
                    bw[from as usize].fetch_sub(g.vw[v], Ordering::Relaxed);
                    bw[to as usize].fetch_add(g.vw[v], Ordering::Relaxed);
                });
            }

            // Moved-edge offsets, shared by the ΔJ reduction and the delta
            // conn-table update.
            let off = {
                let _k = crate::par::ledger::kernel("refine/jet_loop:moved_offsets");
                pool.scan_exclusive(moves.len(), |idx| g.degree(moves[idx]) as u64)
            };
            let moved_edges = off[moves.len()];

            // ΔJ: edge-parallel reduction over the moved incident edges
            // only, instead of a full 2m-edge re-reduction per iteration.
            let delta = {
                let marks = &ws.moved_marks;
                let old = &ws.old_block;
                let cur_r = &cur;
                let off_r = &off;
                let moves_r = &moves;
                let _k = crate::par::ledger::kernel("refine/jet_loop:delta_j");
                pool.parallel_reduce(
                    moved_edges as usize,
                    0f64,
                    |e| {
                        // Owner of slot e: off[i] <= e < off[i+1].
                        let i = off_r.partition_point(|&x| x <= e as u64) - 1;
                        let v = moves_r[i] as usize;
                        let j = g.xadj[v] as usize + (e - off_r[i] as usize);
                        let u = g.adj[j] as usize;
                        let w = g.ew[j];
                        let v_new = cur_r[v];
                        let v_old = old[v];
                        // An edge between two moved endpoints is enumerated
                        // from both sides (factor 1 each); an edge to an
                        // unmoved neighbor only from this side, but its
                        // reverse slot contributes the same (factor 2).
                        let (u_old, u_new, fac) = if marks.is_marked(u, epoch) {
                            (old[u], cur_r[u], 1.0)
                        } else {
                            (cur_r[u], cur_r[u], 2.0)
                        };
                        fac * w * (pair_cost(obj, v_new, u_new) - pair_cost(obj, v_old, u_old))
                    },
                    |a, b| a + b,
                )
            };
            j_cur += delta * directed_scale(obj);

            // Conn-table update: the paper's two §4.2 strategies.
            let use_delta = match cfg.conn_update {
                ConnUpdate::Refill => false,
                ConnUpdate::Delta => true,
                ConnUpdate::Auto => (moved_edges as usize) * 2 < g.num_directed(),
            };
            if use_delta {
                stats.conn_delta_rounds += 1;
                conn.update_delta_with_offsets(pool, g, &cur, &moves, &ws.old_block, &off);
            } else {
                stats.conn_refill_rounds += 1;
                ws.affected_set_into(pool, g, &moves, &mut affected);
                conn.refill(pool, g, &cur, &affected);
            }

            rounds_since_sync += 1;
            if rounds_since_sync >= cfg.resync_every.max(1) {
                j_cur = eval_objective(pool, g, el, &cur, obj);
                rounds_since_sync = 0;
                stats.objective_resyncs += 1;
            }
        }

        // Lines 16–21: best-solution tracking (on the tracked objective).
        let cur_max = max_bw(&ws.bw, k);
        if cur_max <= l_max {
            let j = j_cur;
            let prev_best_j = best_j;
            if !best_balanced || j < best_j {
                best.copy_from_slice(&cur);
                best_j = j;
                best_balanced = true;
                best_imb = cur_max;
            }
            if j < cfg.phi * prev_best_j {
                i = 0;
            }
        } else if !best_balanced && cur_max < best_imb {
            best.copy_from_slice(&cur);
            best_imb = cur_max;
            best_j = j_cur;
            i = 0;
        }
        // Fixed-point detection: one empty LP round is not convergence —
        // vertices locked in the previous round are unlocked for the next
        // one. Two consecutive empty rounds on a balanced partition are.
        if moves.is_empty() {
            empty_rounds += 1;
            if empty_rounds >= 2 && cur_max <= l_max {
                break;
            }
        } else {
            empty_rounds = 0;
        }
    }

    // One exact reduction for the reported objective: bounds any leftover
    // incremental drift in what callers observe.
    stats.final_objective = eval_objective(pool, g, el, &best, obj);
    *part = best;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{comm_cost, edge_cut, is_balanced, l_max as lmax_of};
    use crate::rng::Rng;
    use crate::topology::Machine;

    #[test]
    #[cfg_attr(miri, ignore)] // miri: full multi-round jet solve, too slow under the interpreter
    fn refines_random_mapping_to_balanced_low_cost() {
        let g = gen::grid2d(24, 24, false);
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let k = h.k();
        let lmax = lmax_of(g.total_vweight(), k, 0.03);
        let mut rng = Rng::new(1);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let before = comm_cost(&g, &part, &h);
        let stats = jet_refine(
            &pool, &g, &el, &mut part, k, lmax, &Objective::Comm(&h), &JetConfig::default(),
        );
        let after = comm_cost(&g, &part, &h);
        assert!(is_balanced(&g, &part, k, 0.031), "not balanced");
        assert!(after < before * 0.8, "{before} -> {after}");
        assert!(stats.lp_steps > 0);
        assert!((stats.final_objective - after).abs() < 1e-6 * after.max(1.0));
    }

    #[test]
    fn cancelled_token_stops_before_the_first_round() {
        let g = gen::grid2d(24, 24, false);
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let k = h.k();
        let lmax = lmax_of(g.total_vweight(), k, 0.03);
        let mut rng = Rng::new(1);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let snapshot = part.clone();
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let cfg = JetConfig::default();
        cfg.cancel.cancel();
        let stats = jet_refine(&pool, &g, &el, &mut part, k, lmax, &Objective::Comm(&h), &cfg);
        assert_eq!(stats.iterations, 0, "no round may run after cancellation");
        assert_eq!(part, snapshot, "cancelled run must leave the input mapping intact");
        // The reported objective is still an exact reduction of the input.
        assert!((stats.final_objective - comm_cost(&g, &part, &h)).abs() < 1e-6);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 1500-vertex rgg + multi-thread jet solve, too slow
    fn recovers_balance_from_overloaded_start() {
        let g = gen::rgg(1_500, 0.06, 3);
        let h = Machine::hier("4:2", "1:10").unwrap();
        let k = h.k();
        let lmax = lmax_of(g.total_vweight(), k, 0.05);
        // 80% in block 0.
        let mut rng = Rng::new(5);
        let mut part: Vec<Block> = (0..g.n())
            .map(|_| if rng.f64() < 0.8 { 0 } else { rng.below(k as u64) as Block })
            .collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(2);
        let stats = jet_refine(
            &pool, &g, &el, &mut part, k, lmax, &Objective::Comm(&h), &JetConfig::default(),
        );
        assert!(is_balanced(&g, &part, k, 0.051), "still imbalanced");
        assert!(stats.weak_steps + stats.strong_steps > 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: full jet solve on a 400-vertex stencil, too slow
    fn works_with_edge_cut_objective() {
        let g = gen::stencil9(20, 20, 7);
        let k = 8;
        let lmax = lmax_of(g.total_vweight(), k, 0.03);
        let mut rng = Rng::new(9);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let before = edge_cut(&g, &part);
        jet_refine(
            &pool,
            &g,
            &el,
            &mut part,
            k,
            lmax,
            &Objective::Cut,
            &JetConfig { filter: Filter::JetNegative { c_factor: 0.25 }, ..Default::default() },
        );
        let after = edge_cut(&g, &part);
        assert!(after < before * 0.7, "{before} -> {after}");
        assert!(is_balanced(&g, &part, k, 0.031));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: repeated full jet solves, too slow
    fn ultra_at_least_as_good_on_average() {
        let g = gen::grid2d(20, 20, false);
        let h = Machine::hier("2:4", "1:10").unwrap();
        let k = h.k();
        let lmax = lmax_of(g.total_vweight(), k, 0.03);
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut sum_def = 0.0;
        let mut sum_ultra = 0.0;
        for seed in 0..3u64 {
            let mut rng = Rng::new(seed);
            let init: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
            let mut p1 = init.clone();
            jet_refine(&pool, &g, &el, &mut p1, k, lmax, &Objective::Comm(&h), &JetConfig::default());
            let mut p2 = init;
            jet_refine(
                &pool, &g, &el, &mut p2, k, lmax, &Objective::Comm(&h),
                &JetConfig::default().ultra(),
            );
            sum_def += comm_cost(&g, &p1, &h);
            sum_ultra += comm_cost(&g, &p2, &h);
        }
        assert!(sum_ultra <= sum_def * 1.05, "ultra much worse: {sum_ultra} vs {sum_def}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: two full jet solves, too slow
    fn conn_strategies_agree_on_final_mapping() {
        // Integer edge weights ⇒ the delta updates and the incremental
        // objective are exact, so the full controller trajectory must be
        // identical under every conn-update strategy.
        let g = gen::stencil9(22, 22, 3);
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let k = h.k();
        let lmax = lmax_of(g.total_vweight(), k, 0.03);
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut rng = Rng::new(17);
        let init: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let mut results = Vec::new();
        for strat in [ConnUpdate::Refill, ConnUpdate::Delta, ConnUpdate::Auto] {
            let mut p = init.clone();
            let cfg = JetConfig { conn_update: strat, ..Default::default() };
            let stats = jet_refine(&pool, &g, &el, &mut p, k, lmax, &Objective::Comm(&h), &cfg);
            match strat {
                ConnUpdate::Refill => assert_eq!(stats.conn_delta_rounds, 0),
                ConnUpdate::Delta => assert_eq!(stats.conn_refill_rounds, 0),
                ConnUpdate::Auto => {}
            }
            results.push(p);
        }
        assert_eq!(results[0], results[1], "refill vs delta");
        assert_eq!(results[0], results[2], "refill vs auto");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: two full jet solves, too slow
    fn incremental_objective_matches_per_round_resync() {
        // resync_every = 1 re-reduces exactly every round (the old
        // behavior); with integer weights the incremental tracker must
        // produce the same trajectory and the same final mapping.
        let g = gen::stencil9(20, 20, 5);
        let h = Machine::hier("4:2", "1:10").unwrap();
        let k = h.k();
        let lmax = lmax_of(g.total_vweight(), k, 0.03);
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut rng = Rng::new(23);
        let init: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let mut p_exact = init.clone();
        let exact_cfg = JetConfig { resync_every: 1, ..Default::default() };
        let s_exact =
            jet_refine(&pool, &g, &el, &mut p_exact, k, lmax, &Objective::Comm(&h), &exact_cfg);
        assert!(s_exact.objective_resyncs > 0);
        let mut p_incr = init;
        let incr_cfg = JetConfig { resync_every: 1_000_000, ..Default::default() };
        let s_incr =
            jet_refine(&pool, &g, &el, &mut p_incr, k, lmax, &Objective::Comm(&h), &incr_cfg);
        assert_eq!(p_exact, p_incr);
        assert!(
            (s_exact.final_objective - s_incr.final_objective).abs()
                < 1e-9 * s_exact.final_objective.max(1.0)
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: two full jet solves, too slow
    fn workspace_reuse_matches_fresh_workspace() {
        let g = gen::grid2d(20, 20, false);
        let h = Machine::hier("2:2", "1:10").unwrap();
        let k = h.k();
        let lmax = lmax_of(g.total_vweight(), k, 0.03);
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut ws = RefineWorkspace::with_capacity(g.n(), k);
        for seed in 0..3u64 {
            let mut rng = Rng::new(seed);
            let init: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
            let mut p_shared = init.clone();
            jet_refine_with(
                &pool, &g, &el, &mut p_shared, k, lmax, &Objective::Comm(&h),
                &JetConfig::default(), &mut ws,
            );
            let mut p_fresh = init;
            jet_refine(
                &pool, &g, &el, &mut p_fresh, k, lmax, &Objective::Comm(&h),
                &JetConfig::default(),
            );
            assert_eq!(p_shared, p_fresh, "seed={seed}");
        }
    }

    #[test]
    fn k1_graceful() {
        let g = gen::grid2d(5, 5, false);
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut part = vec![0 as Block; g.n()];
        let stats = jet_refine(
            &pool, &g, &el, &mut part, 1, g.total_vweight(), &Objective::Cut, &JetConfig::default(),
        );
        assert_eq!(stats.iterations, 0);
    }
}
