//! The refinement controller — paper Algorithm 6.
//!
//! Alternates unconstrained label propagation (balanced state) with weak /
//! strong rebalancing (unbalanced state; at most two consecutive weak
//! steps before a strong one), keeping the best feasible mapping found.
//! The iteration counter resets whenever the objective improves by more
//! than the factor `φ = 0.999` (or balance improves while infeasible), and
//! the loop ends after `iter_limit` (12; 18 for the *ultra* flavor)
//! iterations without significant progress.

use super::gains::ConnTable;
use super::jet_lp::{Filter, JetLp};
use super::rebalance::{rebalance, Strength};
use super::Objective;
use crate::graph::{CsrGraph, EdgeList};
use crate::par::Pool;
use crate::partition::block_weights;
use crate::{Block, VWeight, Vertex};

/// Controller configuration (constants transferred from Jet).
#[derive(Clone, Debug)]
pub struct JetConfig {
    /// Iterations without significant improvement before stopping (12).
    pub iter_limit: usize,
    /// Consecutive weak rebalances before a strong one (2).
    pub weak_limit: usize,
    /// Significant-improvement factor φ (0.999).
    pub phi: f64,
    /// First-filter flavor for LP.
    pub filter: Filter,
    /// Use the mapping objective `J` for the rebalancing loss too
    /// (ablation A2; the paper ships with edge-cut loss: `false`).
    pub rebalance_with_comm_obj: bool,
    /// Seed for the deterministic random choices in rebalancing.
    pub seed: u64,
}

impl Default for JetConfig {
    fn default() -> Self {
        JetConfig {
            iter_limit: 12,
            weak_limit: 2,
            phi: 0.999,
            filter: Filter::NonNegative,
            rebalance_with_comm_obj: false,
            seed: 0,
        }
    }
}

impl JetConfig {
    /// The *ultra* flavor: 18 refinement iterations.
    pub fn ultra(mut self) -> Self {
        self.iter_limit = 18;
        self
    }
}

/// Statistics of one controller run.
#[derive(Clone, Copy, Debug, Default)]
pub struct RefineStats {
    pub iterations: usize,
    pub lp_steps: usize,
    pub weak_steps: usize,
    pub strong_steps: usize,
    pub moves: usize,
    /// Objective of the returned mapping.
    pub final_objective: f64,
}

/// Evaluate the controller objective with an edge-parallel reduction.
fn eval_objective(pool: &Pool, g: &CsrGraph, el: &EdgeList, part: &[Block], obj: &Objective) -> f64 {
    match obj {
        Objective::Cut => {
            pool.reduce_sum_f64(g.num_directed(), |i| {
                let u = el.eu[i] as usize;
                let v = g.adj[i] as usize;
                if part[u] != part[v] {
                    g.ew[i]
                } else {
                    0.0
                }
            }) / 2.0
        }
        Objective::Comm(h) => crate::partition::comm_cost_par(pool, g, &el.eu, part, h),
        Objective::CommMat(m) => pool.reduce_sum_f64(g.num_directed(), |i| {
            let u = el.eu[i] as usize;
            let v = g.adj[i] as usize;
            g.ew[i] * m.get(part[u], part[v])
        }),
    }
}

/// Run Algorithm 6 on `part` in place. Returns run statistics.
#[allow(clippy::too_many_arguments)]
pub fn jet_refine(
    pool: &Pool,
    g: &CsrGraph,
    el: &EdgeList,
    part: &mut Vec<Block>,
    k: usize,
    l_max: VWeight,
    obj: &Objective,
    cfg: &JetConfig,
) -> RefineStats {
    let n = g.n();
    let mut stats = RefineStats::default();
    if n == 0 || k <= 1 {
        stats.final_objective = eval_objective(pool, g, el, part, obj);
        return stats;
    }
    // §Perf opt 1: materialize the distance matrix once per refine call —
    // O(1) distance lookups in the gain kernels instead of the O(ℓ)
    // division oracle.
    let dmat = obj.materialize();
    let obj: &Objective = &match &dmat {
        Some(m) => Objective::CommMat(m),
        None => *obj,
    };

    let mut cur = part.clone();
    let mut bw = block_weights(g, &cur, k);
    let conn = ConnTable::build(pool, g, el, &cur, k);
    let mut lp = JetLp::new(n);

    let max_bw = |bw: &[VWeight]| bw.iter().copied().max().unwrap_or(0);

    // Best (returned) mapping state.
    let mut best = part.clone();
    let mut best_balanced = max_bw(&bw) <= l_max;
    let mut best_j = eval_objective(pool, g, el, &best, obj);
    let mut best_imb = max_bw(&bw);

    let mut i = 0usize;
    let mut i_w = 0usize;
    let mut empty_rounds = 0usize;
    let reb_obj_comm = cfg.rebalance_with_comm_obj;

    while i < cfg.iter_limit {
        i += 1;
        stats.iterations += 1;

        let (moves, dests): (Vec<Vertex>, Vec<Block>) = if max_bw(&bw) <= l_max {
            stats.lp_steps += 1;
            i_w = 0;
            let moves = lp.run(pool, g, &conn, &cur, obj, cfg.filter);
            let dests = moves.iter().map(|&v| lp.dest_of(v)).collect();
            (moves, dests)
        } else {
            let strength = if i_w < cfg.weak_limit {
                i_w += 1;
                stats.weak_steps += 1;
                Strength::Weak
            } else {
                i_w = 0;
                stats.strong_steps += 1;
                Strength::Strong
            };
            let reb_obj = if reb_obj_comm { *obj } else { Objective::Cut };
            let (moves, dest_arr) = rebalance(
                pool,
                g,
                &conn,
                &cur,
                &bw,
                k,
                l_max,
                &reb_obj,
                strength,
                cfg.seed ^ (i as u64) << 8,
            );
            let dests = moves.iter().map(|&v| dest_arr[v as usize]).collect();
            (moves, dests)
        };

        // Move(M, Π''): apply, update block weights and the conn table.
        stats.moves += moves.len();
        for (idx, &v) in moves.iter().enumerate() {
            let vi = v as usize;
            let to = dests[idx];
            bw[cur[vi] as usize] -= g.vw[vi];
            bw[to as usize] += g.vw[vi];
            cur[vi] = to;
        }
        if !moves.is_empty() {
            let affected = ConnTable::affected_set(g, &moves);
            conn.refill(pool, g, &cur, &affected);
        }

        // Lines 16–21: best-solution tracking.
        let cur_max = max_bw(&bw);
        if cur_max <= l_max {
            let j = eval_objective(pool, g, el, &cur, obj);
            let prev_best_j = best_j;
            if !best_balanced || j < best_j {
                best.copy_from_slice(&cur);
                best_j = j;
                best_balanced = true;
                best_imb = cur_max;
            }
            if j < cfg.phi * prev_best_j {
                i = 0;
            }
        } else if !best_balanced && cur_max < best_imb {
            best.copy_from_slice(&cur);
            best_imb = cur_max;
            best_j = eval_objective(pool, g, el, &cur, obj);
            i = 0;
        }
        // Fixed-point detection: one empty LP round is not convergence —
        // vertices locked in the previous round are unlocked for the next
        // one. Two consecutive empty rounds on a balanced partition are.
        if moves.is_empty() {
            empty_rounds += 1;
            if empty_rounds >= 2 && cur_max <= l_max {
                break;
            }
        } else {
            empty_rounds = 0;
        }
    }

    stats.final_objective = best_j;
    *part = best;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{comm_cost, edge_cut, is_balanced, l_max as lmax_of};
    use crate::rng::Rng;
    use crate::topology::Hierarchy;

    #[test]
    fn refines_random_mapping_to_balanced_low_cost() {
        let g = gen::grid2d(24, 24, false);
        let h = Hierarchy::parse("2:2:2", "1:10:100").unwrap();
        let k = h.k();
        let lmax = lmax_of(g.total_vweight(), k, 0.03);
        let mut rng = Rng::new(1);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let before = comm_cost(&g, &part, &h);
        let stats = jet_refine(
            &pool, &g, &el, &mut part, k, lmax, &Objective::Comm(&h), &JetConfig::default(),
        );
        let after = comm_cost(&g, &part, &h);
        assert!(is_balanced(&g, &part, k, 0.031), "not balanced");
        assert!(after < before * 0.8, "{before} -> {after}");
        assert!(stats.lp_steps > 0);
        assert!((stats.final_objective - after).abs() < 1e-6 * after.max(1.0));
    }

    #[test]
    fn recovers_balance_from_overloaded_start() {
        let g = gen::rgg(1_500, 0.06, 3);
        let h = Hierarchy::parse("4:2", "1:10").unwrap();
        let k = h.k();
        let lmax = lmax_of(g.total_vweight(), k, 0.05);
        // 80% in block 0.
        let mut rng = Rng::new(5);
        let mut part: Vec<Block> = (0..g.n())
            .map(|_| if rng.f64() < 0.8 { 0 } else { rng.below(k as u64) as Block })
            .collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(2);
        let stats = jet_refine(
            &pool, &g, &el, &mut part, k, lmax, &Objective::Comm(&h), &JetConfig::default(),
        );
        assert!(is_balanced(&g, &part, k, 0.051), "still imbalanced");
        assert!(stats.weak_steps + stats.strong_steps > 0);
    }

    #[test]
    fn works_with_edge_cut_objective() {
        let g = gen::stencil9(20, 20, 7);
        let k = 8;
        let lmax = lmax_of(g.total_vweight(), k, 0.03);
        let mut rng = Rng::new(9);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let before = edge_cut(&g, &part);
        jet_refine(
            &pool,
            &g,
            &el,
            &mut part,
            k,
            lmax,
            &Objective::Cut,
            &JetConfig { filter: Filter::JetNegative { c_factor: 0.25 }, ..Default::default() },
        );
        let after = edge_cut(&g, &part);
        assert!(after < before * 0.7, "{before} -> {after}");
        assert!(is_balanced(&g, &part, k, 0.031));
    }

    #[test]
    fn ultra_at_least_as_good_on_average() {
        let g = gen::grid2d(20, 20, false);
        let h = Hierarchy::parse("2:4", "1:10").unwrap();
        let k = h.k();
        let lmax = lmax_of(g.total_vweight(), k, 0.03);
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut sum_def = 0.0;
        let mut sum_ultra = 0.0;
        for seed in 0..3u64 {
            let mut rng = Rng::new(seed);
            let init: Vec<Block> = (0..g.n()).map(|_| rng.below(k as u64) as Block).collect();
            let mut p1 = init.clone();
            jet_refine(&pool, &g, &el, &mut p1, k, lmax, &Objective::Comm(&h), &JetConfig::default());
            let mut p2 = init;
            jet_refine(
                &pool, &g, &el, &mut p2, k, lmax, &Objective::Comm(&h),
                &JetConfig::default().ultra(),
            );
            sum_def += comm_cost(&g, &p1, &h);
            sum_ultra += comm_cost(&g, &p2, &h);
        }
        assert!(sum_ultra <= sum_def * 1.05, "ultra much worse: {sum_ultra} vs {sum_def}");
    }

    #[test]
    fn k1_graceful() {
        let g = gen::grid2d(5, 5, false);
        let el = EdgeList::build(&g);
        let pool = Pool::new(1);
        let mut part = vec![0 as Block; g.n()];
        let stats = jet_refine(
            &pool, &g, &el, &mut part, 1, g.total_vweight(), &Objective::Cut, &JetConfig::default(),
        );
        assert_eq!(stats.iterations, 0);
    }
}
