//! Serial k-way label propagation (Raghavan et al., as used for
//! refinement by Kaffpa/IntMap): visit vertices in random order, move a
//! vertex to the neighboring block with the best strictly-positive gain if
//! the balance constraint stays satisfied. Works for both objectives
//! (edge-cut and `J`), which is exactly how IntMap integrates mapping into
//! the multilevel scheme.

use super::Objective;
use crate::graph::CsrGraph;
use crate::partition::block_weights;
use crate::rng::Rng;
use crate::{Block, VWeight, Vertex};

/// Run `rounds` of serial label propagation; returns the number of moves.
pub fn lp_refine_serial(
    g: &CsrGraph,
    part: &mut [Block],
    k: usize,
    l_max: VWeight,
    obj: &Objective,
    rounds: usize,
    seed: u64,
) -> usize {
    let n = g.n();
    let mut bw = block_weights(g, part, k);
    let mut rng = Rng::new(seed);
    let mut order: Vec<Vertex> = (0..n as Vertex).collect();
    let mut conn: Vec<(Block, f64)> = Vec::with_capacity(32);
    let mut total_moves = 0usize;

    for _round in 0..rounds {
        rng.shuffle(&mut order);
        let mut moves = 0usize;
        for &v in &order {
            let vi = v as usize;
            let from = part[vi];
            // Gather block connectivity of v.
            conn.clear();
            let (nbrs, ws) = g.neighbors_w(v);
            'edges: for (&u, &w) in nbrs.iter().zip(ws) {
                let b = part[u as usize];
                for entry in conn.iter_mut() {
                    if entry.0 == b {
                        entry.1 += w;
                        continue 'edges;
                    }
                }
                conn.push((b, w));
            }
            // Best strictly-positive move respecting balance.
            let mut best: Option<(f64, Block)> = None;
            for &(b, _) in conn.iter() {
                if b == from || bw[b as usize] + g.vw[vi] > l_max {
                    continue;
                }
                let gain = obj.gain(&conn, from, b);
                if gain > 1e-12 && best.map(|(bg, _)| gain > bg).unwrap_or(true) {
                    best = Some((gain, b));
                }
            }
            if let Some((_, to)) = best {
                part[vi] = to;
                bw[from as usize] -= g.vw[vi];
                bw[to as usize] += g.vw[vi];
                moves += 1;
            }
        }
        total_moves += moves;
        if moves == 0 {
            break;
        }
    }
    total_moves
}

/// Serial balance repair: move minimum-loss boundary vertices out of
/// overloaded blocks until every block satisfies `L_max` (the serial
/// counterpart of Alg. 5; used by the IntMap-like baseline whose LP can
/// only preserve balance, not restore it). Returns the number of moves.
pub fn force_balance_serial(
    g: &CsrGraph,
    part: &mut [Block],
    k: usize,
    l_max: VWeight,
    obj: &Objective,
    seed: u64,
) -> usize {
    let n = g.n();
    let mut bw = block_weights(g, part, k);
    let mut moves = 0usize;
    let mut conn: Vec<(Block, f64)> = Vec::with_capacity(32);
    let mut rng = Rng::new(seed);

    for _round in 0..4 * k {
        let Some(over) = (0..k).find(|&b| bw[b] > l_max) else { break };
        // Collect candidate moves out of `over`, cheapest loss first.
        let mut cands: Vec<(f64, Vertex, Block)> = Vec::new();
        for v in 0..n {
            if part[v] != over as Block {
                continue;
            }
            conn.clear();
            let (nbrs, ws) = g.neighbors_w(v as Vertex);
            'edges: for (&u, &w) in nbrs.iter().zip(ws) {
                let b = part[u as usize];
                for e in conn.iter_mut() {
                    if e.0 == b {
                        e.1 += w;
                        continue 'edges;
                    }
                }
                conn.push((b, w));
            }
            let mut best: Option<(f64, Block)> = None;
            for &(b, _) in conn.iter() {
                if b as usize == over || bw[b as usize] + g.vw[v] > l_max {
                    continue;
                }
                let gain = obj.gain(&conn, over as Block, b);
                if best.map(|(bg, _)| gain > bg).unwrap_or(true) {
                    best = Some((gain, b));
                }
            }
            if best.is_none() {
                // Any underloaded block (disconnected destination).
                let start = rng.below_usize(k);
                for i in 0..k {
                    let b = ((start + i) % k) as Block;
                    if b as usize != over && bw[b as usize] + g.vw[v] <= l_max {
                        best = Some((obj.gain(&conn, over as Block, b), b));
                        break;
                    }
                }
            }
            if let Some((gain, b)) = best {
                cands.push((-gain, v as Vertex, b)); // sort by loss ascending
            }
        }
        cands.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut progressed = false;
        for (_, v, dest) in cands {
            if bw[over] <= l_max {
                break;
            }
            let vi = v as usize;
            if bw[dest as usize] + g.vw[vi] > l_max {
                continue;
            }
            part[vi] = dest;
            bw[over] -= g.vw[vi];
            bw[dest as usize] += g.vw[vi];
            moves += 1;
            progressed = true;
        }
        if !progressed {
            break;
        }
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{comm_cost, edge_cut, is_balanced, l_max};
    use crate::topology::Machine;

    fn random_part(n: usize, k: usize, seed: u64) -> Vec<Block> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(k as u64) as Block).collect()
    }

    #[test]
    fn improves_edge_cut() {
        let g = gen::grid2d(20, 20, false);
        let k = 4;
        let lmax = l_max(g.total_vweight(), k, 0.10);
        let mut part = random_part(g.n(), k, 1);
        let before = edge_cut(&g, &part);
        lp_refine_serial(&g, &mut part, k, lmax, &Objective::Cut, 10, 2);
        let after = edge_cut(&g, &part);
        assert!(after < before * 0.8, "{before} -> {after}");
        assert!(is_balanced(&g, &part, k, 0.10 + 1e-9) || before == after);
    }

    #[test]
    fn improves_comm_cost() {
        let g = gen::grid2d(16, 16, false);
        let h = Machine::hier("2:2", "1:10").unwrap();
        let k = h.k();
        let lmax = l_max(g.total_vweight(), k, 0.20);
        let mut part = random_part(g.n(), k, 3);
        let before = comm_cost(&g, &part, &h);
        lp_refine_serial(&g, &mut part, k, lmax, &Objective::Comm(&h), 10, 4);
        let after = comm_cost(&g, &part, &h);
        assert!(after < before, "{before} -> {after}");
    }

    #[test]
    fn comm_objective_prefers_near_blocks() {
        // LP under J should keep cut edges on cheap links when possible;
        // compare against cut-objective result measured in J.
        let g = gen::stencil9(16, 16, 5);
        let h = Machine::hier("4:4", "1:100").unwrap();
        let k = h.k();
        let lmax = l_max(g.total_vweight(), k, 0.25);
        let seed_part = random_part(g.n(), k, 7);

        let mut part_cut = seed_part.clone();
        lp_refine_serial(&g, &mut part_cut, k, lmax, &Objective::Cut, 8, 8);
        let mut part_comm = seed_part;
        lp_refine_serial(&g, &mut part_comm, k, lmax, &Objective::Comm(&h), 8, 8);

        let j_cut = comm_cost(&g, &part_cut, &h);
        let j_comm = comm_cost(&g, &part_comm, &h);
        assert!(j_comm <= j_cut * 1.05, "J-objective did much worse: {j_comm} vs {j_cut}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: 1200-vertex rgg generation dominates, too slow
    fn force_balance_repairs_overload() {
        let g = gen::rgg(1_200, 0.07, 6);
        let k = 8;
        let mut rng = Rng::new(7);
        let mut part: Vec<Block> = (0..g.n())
            .map(|_| if rng.f64() < 0.6 { 0 } else { rng.below(k as u64) as Block })
            .collect();
        let lmax = l_max(g.total_vweight(), k, 0.05);
        let h = Machine::hier("4:2", "1:10").unwrap();
        let moves = force_balance_serial(&g, &mut part, k, lmax, &Objective::Comm(&h), 1);
        assert!(moves > 0);
        assert!(
            crate::partition::max_block_weight(&g, &part, k) <= lmax,
            "still overloaded after repair"
        );
    }

    #[test]
    fn force_balance_noop_when_balanced() {
        let g = gen::grid2d(8, 8, false);
        let mut part: Vec<Block> = (0..g.n()).map(|v| (v % 4) as Block).collect();
        let lmax = l_max(g.total_vweight(), 4, 0.05);
        let moves = force_balance_serial(&g, &mut part, 4, lmax, &Objective::Cut, 1);
        assert_eq!(moves, 0);
    }

    #[test]
    fn never_violates_balance_if_start_balanced() {
        let g = gen::grid2d(12, 12, false);
        let k = 3;
        let lmax = l_max(g.total_vweight(), k, 0.05);
        let mut part: Vec<Block> = (0..g.n()).map(|v| (v % k) as Block).collect();
        lp_refine_serial(&g, &mut part, k, lmax, &Objective::Cut, 5, 1);
        assert!(is_balanced(&g, &part, k, 0.05 + 1e-9));
    }
}
