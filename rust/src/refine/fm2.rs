//! Serial 2-way Fiduccia–Mattheyses refinement with rollback.
//!
//! Used inside the multilevel bisection that powers the recursive k-way
//! substrate ("kaffpa-lite") and the CPU baselines. Minimizes edge-cut
//! between blocks 0/1 under per-side weight caps (the caps differ for
//! unbalanced target splits in recursive bisection).

use super::OrdF64;
use crate::graph::CsrGraph;
use crate::{Block, VWeight, Vertex};
use std::collections::BinaryHeap;

/// Configuration for one FM run.
pub struct Fm2Config {
    /// Maximum weight of block 0 / block 1.
    pub max0: VWeight,
    pub max1: VWeight,
    /// Passes (each pass moves each vertex at most once).
    pub passes: usize,
    /// Abort a pass after this many consecutive non-improving moves.
    pub stall_limit: usize,
}

impl Default for Fm2Config {
    fn default() -> Self {
        Fm2Config { max0: VWeight::MAX, max1: VWeight::MAX, passes: 3, stall_limit: 400 }
    }
}

/// Refine a bisection in place; returns the edge-cut improvement.
pub fn fm2_refine(g: &CsrGraph, part: &mut [Block], cfg: &Fm2Config) -> f64 {
    let n = g.n();
    let mut total_gain = 0.0;
    let mut bw = [0 as VWeight; 2];
    for v in 0..n {
        bw[part[v] as usize] += g.vw[v];
    }
    let maxw = [cfg.max0, cfg.max1];

    // Internal/external connectivity per vertex.
    let gain_of = |part: &[Block], v: usize| -> f64 {
        let (nbrs, ws) = g.neighbors_w(v as Vertex);
        let mut int = 0.0;
        let mut ext = 0.0;
        for (&u, &w) in nbrs.iter().zip(ws) {
            if part[u as usize] == part[v] {
                int += w;
            } else {
                ext += w;
            }
        }
        ext - int
    };

    for _pass in 0..cfg.passes {
        let mut heap: BinaryHeap<(OrdF64, Vertex)> = BinaryHeap::new();
        let mut cur_gain = vec![0.0f64; n];
        for v in 0..n {
            cur_gain[v] = gain_of(part, v);
            heap.push((OrdF64(cur_gain[v]), v as Vertex));
        }
        let mut locked = vec![false; n];
        let mut moves: Vec<Vertex> = Vec::new();
        let mut acc = 0.0;
        let mut best_acc = 0.0;
        let mut best_len = 0usize;
        let mut stall = 0usize;

        while let Some((OrdF64(gain), v)) = heap.pop() {
            let vi = v as usize;
            if locked[vi] || gain != cur_gain[vi] {
                continue; // stale entry
            }
            let from = part[vi] as usize;
            let to = 1 - from;
            if bw[to] + g.vw[vi] > maxw[to] {
                // Cannot move without violating the cap; lock in place.
                locked[vi] = true;
                continue;
            }
            // Execute the move.
            locked[vi] = true;
            part[vi] = to as Block;
            bw[from] -= g.vw[vi];
            bw[to] += g.vw[vi];
            acc += gain;
            moves.push(v);
            if acc > best_acc + 1e-12 {
                best_acc = acc;
                best_len = moves.len();
                stall = 0;
            } else {
                stall += 1;
                if stall > cfg.stall_limit {
                    break;
                }
            }
            // Update unlocked neighbors.
            for &u in g.neighbors(v) {
                let ui = u as usize;
                if !locked[ui] {
                    cur_gain[ui] = gain_of(part, ui);
                    heap.push((OrdF64(cur_gain[ui]), u));
                }
            }
        }

        // Rollback past the best prefix.
        for &v in &moves[best_len..] {
            let vi = v as usize;
            let from = part[vi] as usize;
            let to = 1 - from;
            part[vi] = to as Block;
            bw[from] -= g.vw[vi];
            bw[to] += g.vw[vi];
        }
        total_gain += best_acc;
        if best_acc <= 1e-12 {
            break;
        }
    }
    total_gain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::edge_cut;
    use crate::rng::Rng;

    #[test]
    fn improves_random_bisection_of_grid() {
        let g = gen::grid2d(16, 16, false);
        let mut rng = Rng::new(1);
        let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(2) as Block).collect();
        let before = edge_cut(&g, &part);
        let half = g.total_vweight() / 2 + g.total_vweight() / 10;
        let gain = fm2_refine(&g, &mut part, &Fm2Config { max0: half, max1: half, ..Default::default() });
        let after = edge_cut(&g, &part);
        assert!(after < before, "no improvement: {before} -> {after}");
        assert!((before - after - gain).abs() < 1e-6, "gain accounting off");
    }

    #[test]
    fn respects_weight_caps() {
        let g = gen::grid2d(10, 10, false);
        let mut part: Vec<Block> = (0..g.n()).map(|v| (v % 2) as Block).collect();
        let cap = 60;
        fm2_refine(&g, &mut part, &Fm2Config { max0: cap, max1: cap, ..Default::default() });
        let w0: i64 = (0..g.n()).filter(|&v| part[v] == 0).map(|v| g.vw[v]).sum();
        let w1: i64 = (0..g.n()).filter(|&v| part[v] == 1).map(|v| g.vw[v]).sum();
        assert!(w0 <= cap && w1 <= cap, "caps violated: {w0} {w1}");
    }

    #[test]
    fn unscrambles_alternating_path() {
        // Path of 32 vertices with alternating blocks: FM's cascading
        // positive moves must drive the cut down to a near-contiguous
        // split (optimal cut = 1).
        let g = gen::grid2d(32, 1, false);
        let mut part: Vec<Block> = (0..32).map(|v| (v % 2) as Block).collect();
        let before = edge_cut(&g, &part);
        fm2_refine(&g, &mut part, &Fm2Config { max0: 18, max1: 18, passes: 16, ..Default::default() });
        let after = edge_cut(&g, &part);
        assert!(after <= 5.0, "cut {before} -> {after}");
    }

    #[test]
    fn never_worsens() {
        let g = gen::rgg(400, 0.1, 2);
        for seed in 0..3 {
            let mut rng = Rng::new(seed);
            let mut part: Vec<Block> = (0..g.n()).map(|_| rng.below(2) as Block).collect();
            let before = edge_cut(&g, &part);
            let cap = g.total_vweight();
            fm2_refine(&g, &mut part, &Fm2Config { max0: cap, max1: cap, ..Default::default() });
            assert!(edge_cut(&g, &part) <= before + 1e-9);
        }
    }
}
