//! Phase timing, run accounting (feeds Table 2 and the speedup plots),
//! and model-aware mapping quality.

use crate::graph::CsrGraph;
use crate::par::cost::{DeviceTimer, Measurement};
use crate::topology::Machine;
use crate::Block;
use std::collections::BTreeMap;

/// Quality of one mapping under a machine model.
#[derive(Clone, Copy, Debug)]
pub struct MappingQuality {
    /// `J(C, D, Π)`, distances answered by the model's oracle — valid for
    /// any [`Machine`], never materializes `k × k`.
    pub comm_cost: f64,
    /// Edge-cut `Σ_{i<j} ω(E_ij)` (model-independent).
    pub edge_cut: f64,
    /// Achieved imbalance `max_i c(V_i)·k / c(V) − 1`.
    pub imbalance: f64,
}

/// Evaluate a mapping against a machine model (the `heipa eval` path and
/// any caller that wants all three headline numbers at once).
pub fn mapping_quality(g: &CsrGraph, part: &[Block], m: &Machine) -> MappingQuality {
    MappingQuality {
        comm_cost: crate::partition::comm_cost(g, part, m),
        edge_cut: crate::partition::edge_cut(g, part),
        imbalance: crate::partition::imbalance(g, part, m.k()),
    }
}

/// The pipeline phases the paper reports in Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Matching (the paper's "Coarsening" row).
    Coarsening,
    Contraction,
    InitialPartitioning,
    Uncontraction,
    RefineRebalance,
    Misc,
}

impl Phase {
    pub fn label(self) -> &'static str {
        match self {
            Phase::Coarsening => "Coarsening",
            Phase::Contraction => "Contraction",
            Phase::InitialPartitioning => "Init. Part.",
            Phase::Uncontraction => "Uncontr.",
            Phase::RefineRebalance => "Refine + Reb.",
            Phase::Misc => "Misc",
        }
    }

    pub fn all() -> [Phase; 6] {
        [
            Phase::Coarsening,
            Phase::Contraction,
            Phase::InitialPartitioning,
            Phase::Uncontraction,
            Phase::RefineRebalance,
            Phase::Misc,
        ]
    }
}

/// Accumulates per-phase host + modeled-device time, plus the final
/// matched fraction of every coarsening level (recorded by the
/// multilevel hierarchy builder after its bounded two-hop fallback).
#[derive(Clone, Debug, Default)]
pub struct PhaseBreakdown {
    device_ms: BTreeMap<Phase, f64>,
    host_ms: BTreeMap<Phase, f64>,
    matched: Vec<f64>,
}

impl PhaseBreakdown {
    pub fn add(&mut self, phase: Phase, m: Measurement) {
        *self.device_ms.entry(phase).or_insert(0.0) += m.device_ms;
        *self.host_ms.entry(phase).or_insert(0.0) += m.host_ms;
    }

    /// Time a closure, attributing it to `phase`.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t = DeviceTimer::start();
        let out = f();
        self.add(phase, t.stop());
        out
    }

    /// Time a *CPU-side* phase (e.g. initial partitioning, which the paper
    /// deliberately runs on the host): wall-clock is charged as its device
    /// time, since the device timeline waits for the host here.
    pub fn time_cpu<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t = DeviceTimer::start();
        let out = f();
        let mut m = t.stop();
        m.device_ms = m.host_ms;
        self.add(phase, m);
        out
    }

    pub fn device_ms(&self, phase: Phase) -> f64 {
        self.device_ms.get(&phase).copied().unwrap_or(0.0)
    }

    pub fn host_ms(&self, phase: Phase) -> f64 {
        self.host_ms.get(&phase).copied().unwrap_or(0.0)
    }

    pub fn total_device_ms(&self) -> f64 {
        self.device_ms.values().sum()
    }

    pub fn total_host_ms(&self) -> f64 {
        self.host_ms.values().sum()
    }

    /// Percentage share of a phase (modeled device time).
    pub fn share(&self, phase: Phase) -> f64 {
        let total = self.total_device_ms();
        if total == 0.0 {
            0.0
        } else {
            100.0 * self.device_ms(phase) / total
        }
    }

    /// Record the final matched fraction of one coarsening level (after
    /// every two-hop fallback pass ran).
    pub fn record_matched_fraction(&mut self, frac: f64) {
        self.matched.push(frac);
    }

    /// Final matched fraction per coarsening level, finest first.
    pub fn matched_fractions(&self) -> &[f64] {
        &self.matched
    }

    /// Merge another breakdown into this one.
    pub fn merge(&mut self, other: &PhaseBreakdown) {
        for (p, v) in &other.device_ms {
            *self.device_ms.entry(*p).or_insert(0.0) += v;
        }
        for (p, v) in &other.host_ms {
            *self.host_ms.entry(*p).or_insert(0.0) += v;
        }
        self.matched.extend_from_slice(&other.matched);
    }

    /// Table-2-style row dump: `(label, share %, device ms)`.
    pub fn rows(&self) -> Vec<(&'static str, f64, f64)> {
        Phase::all()
            .into_iter()
            .map(|p| (p.label(), self.share(p), self.device_ms(p)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_sum_to_100() {
        let mut pb = PhaseBreakdown::default();
        let pool = crate::par::Pool::new(1);
        pb.time(Phase::Coarsening, || pool.parallel_for(1_000, |_| {}));
        pb.time(Phase::RefineRebalance, || pool.parallel_for(3_000, |_| {}));
        let total: f64 = Phase::all().iter().map(|&p| pb.share(p)).sum();
        assert!((total - 100.0).abs() < 1e-9);
        assert!(pb.share(Phase::RefineRebalance) > pb.share(Phase::Coarsening));
    }

    #[test]
    fn mapping_quality_agrees_with_partition_metrics() {
        let g = crate::graph::gen::grid2d(8, 8, false);
        let m = Machine::parse_spec("torus:2x2").unwrap();
        let part: Vec<Block> = (0..g.n()).map(|v| (v % 4) as Block).collect();
        let q = mapping_quality(&g, &part, &m);
        assert_eq!(q.comm_cost, crate::partition::comm_cost(&g, &part, &m));
        assert_eq!(q.edge_cut, crate::partition::edge_cut(&g, &part));
        assert_eq!(q.imbalance, crate::partition::imbalance(&g, &part, 4));
    }

    #[test]
    fn merge_accumulates() {
        let mut a = PhaseBreakdown::default();
        let mut b = PhaseBreakdown::default();
        let pool = crate::par::Pool::new(1);
        a.time(Phase::Misc, || pool.parallel_for(100, |_| {}));
        b.time(Phase::Misc, || pool.parallel_for(100, |_| {}));
        let before = a.device_ms(Phase::Misc);
        a.merge(&b);
        assert!(a.device_ms(Phase::Misc) > before);
    }
}
