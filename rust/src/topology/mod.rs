//! Machine topologies for the hierarchical process mapping problem.
//!
//! A supercomputer is described by a hierarchy `H = a_1 : … : a_ℓ`
//! (each processor has `a_1` PEs, each node `a_2` processors, …) and a
//! distance vector `D = d_1 : … : d_ℓ` (cost factor between PEs sharing
//! only a level-`i` component). PE ids are mixed-radix with `a_1` fastest.
//!
//! The homogeneous [`Hierarchy`] is one machine model among several: the
//! [`model`] subsystem defines the [`model::MachineModel`] trait
//! (tori, fat-trees, dragonflies, heterogeneous node lists, explicit
//! distance-matrix files) plus the [`model::DistanceOracle`] that every
//! hot loop consults instead of materializing `k × k` matrices.

pub mod model;

pub use model::{
    parse_topology, DistanceOracle, Dragonfly, FatTree, HeteroNodes, Machine, MachineModel,
    MatrixModel, OracleRow, Torus, DENSE_K_MAX,
};

use crate::Block;
use anyhow::{bail, Result};

/// A hierarchical machine topology (paper §2, HPMP definition).
#[derive(Clone, Debug, PartialEq)]
pub struct Hierarchy {
    /// Fan-outs `a_1 … a_ℓ` (innermost first).
    pub a: Vec<u32>,
    /// Distances `d_1 … d_ℓ` (innermost first), `d_i` strictly increasing
    /// in well-formed systems but not required.
    pub d: Vec<f64>,
}

impl Hierarchy {
    pub fn new(a: Vec<u32>, d: Vec<f64>) -> Result<Self> {
        if a.is_empty() || a.len() != d.len() {
            bail!("hierarchy and distance must be non-empty and equal length");
        }
        if a.iter().any(|&x| x == 0) {
            bail!("hierarchy fan-outs must be positive");
        }
        // NaN or negative distances would silently poison every downstream
        // objective (J sums, gain tables, QAP deltas) — reject them here.
        if d.iter().any(|x| !x.is_finite() || *x < 0.0) {
            bail!("hierarchy distances must be finite and non-negative, got {d:?}");
        }
        Ok(Hierarchy { a, d })
    }

    /// Parse `"4:8:6"` + `"1:10:100"`.
    pub fn parse(hier: &str, dist: &str) -> Result<Self> {
        let a: Vec<u32> = hier
            .split(':')
            .map(|t| t.trim().parse::<u32>().map_err(Into::into))
            .collect::<Result<_>>()?;
        let d: Vec<f64> = dist
            .split(':')
            .map(|t| t.trim().parse::<f64>().map_err(Into::into))
            .collect::<Result<_>>()?;
        Self::new(a, d)
    }

    /// Number of levels ℓ.
    pub fn levels(&self) -> usize {
        self.a.len()
    }

    /// Total number of PEs, `k = Π a_i`.
    pub fn k(&self) -> usize {
        self.a.iter().map(|&x| x as usize).product()
    }

    /// Distance factor `D_xy` between PEs `x` and `y` — implicit O(ℓ)
    /// oracle: divide out fan-outs until the ids coincide.
    #[inline]
    pub fn distance(&self, x: Block, y: Block) -> f64 {
        debug_assert!(
            (x as usize) < self.k() && (y as usize) < self.k(),
            "PE id out of range: distance({x}, {y}) on a k={} hierarchy",
            self.k()
        );
        if x == y {
            return 0.0;
        }
        let (mut x, mut y) = (x, y);
        for i in 0..self.a.len() {
            x /= self.a[i];
            y /= self.a[i];
            if x == y {
                return self.d[i];
            }
        }
        *self.d.last().unwrap()
    }

    /// Materialized `k × k` distance matrix (O(k²) space, O(1) lookup —
    /// the paper's simplest distance representation, used by the offload
    /// kernels and for small k).
    pub fn distance_matrix(&self) -> DistanceMatrix {
        DistanceMatrix::from_fn(self.k(), |x, y| self.distance(x, y))
    }

    /// The adaptive imbalance ε′ of SharedMap (paper Eq. 2):
    ///
    /// `ε′ = ((1+ε) · k′·c(V) / (k·c(V′)))^(1/depth) − 1`
    ///
    /// where `c(V)` is the total weight of the original graph, `c(V′)` of
    /// the current subgraph, `k` the total PEs, `k′` the PEs the subgraph
    /// will host, and `depth` the remaining hierarchy depth.
    pub fn adaptive_imbalance(
        eps: f64,
        total_weight: i64,
        sub_weight: i64,
        k_total: usize,
        k_sub: usize,
        depth: usize,
    ) -> f64 {
        debug_assert!(depth >= 1 && sub_weight > 0);
        let ratio = (1.0 + eps) * (k_sub as f64 * total_weight as f64)
            / (k_total as f64 * sub_weight as f64);
        ratio.powf(1.0 / depth as f64) - 1.0
    }

    /// Group count and per-group PE span at hierarchy level `i`
    /// (1-based from the innermost). Partitioning at level `i` splits into
    /// `a_i` blocks, each covering `prod_{j<i} a_j` PEs.
    ///
    /// # Panics
    /// `level` is 1-based: level 0 has no meaning (it used to fall out as
    /// an implicit empty product) and levels past `ℓ` name no hierarchy
    /// component — both are hard errors.
    pub fn pes_per_block_at_level(&self, level: usize) -> usize {
        assert!(
            (1..=self.a.len()).contains(&level),
            "pes_per_block_at_level: level {level} out of range 1..={} (levels are 1-based)",
            self.a.len()
        );
        self.a[..level - 1].iter().map(|&x| x as usize).product()
    }

    /// Display as `a1:a2:…/d1:d2:…`.
    pub fn label(&self) -> String {
        let a: Vec<String> = self.a.iter().map(|x| x.to_string()).collect();
        let d: Vec<String> = self.d.iter().map(|x| format!("{x}")).collect();
        format!("{}/{}", a.join(":"), d.join(":"))
    }
}

/// Dense `k × k` distance matrix.
#[derive(Clone, Debug)]
pub struct DistanceMatrix {
    pub k: usize,
    m: Vec<f64>,
}

impl DistanceMatrix {
    /// Materialize from a pairwise distance function (any machine model).
    pub fn from_fn(k: usize, f: impl Fn(Block, Block) -> f64) -> DistanceMatrix {
        let mut m = vec![0.0f64; k * k];
        for x in 0..k as Block {
            for y in 0..k as Block {
                m[x as usize * k + y as usize] = f(x, y);
            }
        }
        DistanceMatrix { k, m }
    }

    #[inline]
    pub fn get(&self, x: Block, y: Block) -> f64 {
        self.m[x as usize * self.k + y as usize]
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.m
    }

    /// Row `x` (distances from PE `x` to all PEs).
    #[inline]
    pub fn row(&self, x: Block) -> &[f64] {
        &self.m[x as usize * self.k..(x as usize + 1) * self.k]
    }
}

/// The paper's experimental hierarchies: `H = 4:8:{1..6}`, `D = 1:10:100`.
pub fn paper_hierarchies() -> Vec<Hierarchy> {
    (1..=6)
        .map(|top| Hierarchy::new(vec![4, 8, top], vec![1.0, 10.0, 100.0]).unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h486() -> Hierarchy {
        Hierarchy::parse("4:8:6", "1:10:100").unwrap()
    }

    #[test]
    fn parse_and_k() {
        let h = h486();
        assert_eq!(h.k(), 192);
        assert_eq!(h.levels(), 3);
    }

    #[test]
    fn distance_levels() {
        let h = h486();
        // Same PE.
        assert_eq!(h.distance(0, 0), 0.0);
        // Same processor (ids 0..4).
        assert_eq!(h.distance(0, 3), 1.0);
        // Same node, different processor (ids 0..32).
        assert_eq!(h.distance(0, 4), 10.0);
        assert_eq!(h.distance(3, 31), 10.0);
        // Different node.
        assert_eq!(h.distance(0, 32), 100.0);
        assert_eq!(h.distance(0, 191), 100.0);
    }

    #[test]
    fn distance_symmetric() {
        let h = h486();
        for x in [0u32, 5, 37, 150] {
            for y in [1u32, 9, 64, 191] {
                assert_eq!(h.distance(x, y), h.distance(y, x));
            }
        }
    }

    #[test]
    fn matrix_matches_oracle() {
        let h = Hierarchy::parse("2:3:2", "1:7:50").unwrap();
        let m = h.distance_matrix();
        for x in 0..h.k() as u32 {
            for y in 0..h.k() as u32 {
                assert_eq!(m.get(x, y), h.distance(x, y));
            }
        }
    }

    #[test]
    fn adaptive_imbalance_identity_case() {
        // Top-level call: subgraph == graph, k' == k, depth == 1 → ε' == ε.
        let eps = Hierarchy::adaptive_imbalance(0.03, 1000, 1000, 192, 192, 1);
        assert!((eps - 0.03).abs() < 1e-12);
    }

    #[test]
    fn adaptive_imbalance_shrinks_with_depth() {
        // Full graph at depth 3: ε' = (1.03)^(1/3) − 1 < ε.
        let eps = Hierarchy::adaptive_imbalance(0.03, 1000, 1000, 192, 192, 3);
        assert!(eps < 0.03 && eps > 0.0);
        assert!((eps - (1.03f64.powf(1.0 / 3.0) - 1.0)).abs() < 1e-12);
    }

    #[test]
    fn adaptive_imbalance_rewards_light_subgraphs() {
        // A subgraph lighter than its PE share gets extra slack.
        let light = Hierarchy::adaptive_imbalance(0.03, 1000, 100, 192, 24, 2);
        let exact = Hierarchy::adaptive_imbalance(0.03, 1000, 125, 192, 24, 2);
        assert!(light > exact);
    }

    #[test]
    fn paper_hierarchies_count() {
        let hs = paper_hierarchies();
        assert_eq!(hs.len(), 6);
        assert_eq!(hs[5].k(), 192);
        assert_eq!(hs[0].k(), 32);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Hierarchy::parse("4:0:6", "1:10:100").is_err());
        assert!(Hierarchy::parse("4:8", "1:10:100").is_err());
    }

    #[test]
    fn rejects_nan_and_negative_distances() {
        assert!(Hierarchy::parse("4:8:6", "1:nan:100").is_err());
        assert!(Hierarchy::parse("4:8:6", "1:NaN:100").is_err());
        assert!(Hierarchy::parse("4:8:6", "1:-10:100").is_err());
        assert!(Hierarchy::parse("4:8:6", "1:10:inf").is_err());
        assert!(Hierarchy::new(vec![2, 2], vec![1.0, f64::NAN]).is_err());
        assert!(Hierarchy::new(vec![2, 2], vec![-1.0, 10.0]).is_err());
        // Zero stays legal (edge-cut-style distance vectors).
        assert!(Hierarchy::new(vec![2, 2], vec![0.0, 1.0]).is_ok());
    }

    #[test]
    fn pes_per_block() {
        let h = h486();
        assert_eq!(h.pes_per_block_at_level(3), 32); // top-level blocks host 4*8 PEs
        assert_eq!(h.pes_per_block_at_level(2), 4);
        assert_eq!(h.pes_per_block_at_level(1), 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn pes_per_block_level_zero_is_a_hard_error() {
        h486().pes_per_block_at_level(0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn pes_per_block_level_past_ell_is_a_hard_error() {
        h486().pes_per_block_at_level(4);
    }
}
