//! Dragonfly machine model — groups of routers of nodes.

use super::MachineModel;
use crate::Block;
use anyhow::{bail, Context, Result};

/// A dragonfly: `groups` all-to-all-connected groups, each with `routers`
/// all-to-all-connected routers hosting `nodes` PEs. PE ids are
/// mixed-radix `node + nodes·(router + routers·group)` — nodes fastest,
/// matching the section schedule `[nodes, routers, groups]`.
///
/// Distances are the classic three-tier costs: `d_node` between PEs on
/// the same router, `d_local` within a group (one local link), `d_global`
/// across groups (local–global–local path). Defaults are hop counts
/// `1 / 2 / 5`.
#[derive(Clone, Debug)]
pub struct Dragonfly {
    groups: u32,
    routers: u32,
    nodes: u32,
    d_node: f64,
    d_local: f64,
    d_global: f64,
}

impl Dragonfly {
    pub fn new(
        groups: u32,
        routers: u32,
        nodes: u32,
        d_node: f64,
        d_local: f64,
        d_global: f64,
    ) -> Result<Dragonfly> {
        if groups == 0 || routers == 0 || nodes == 0 {
            bail!("dragonfly dimensions must be positive, got {groups}:{routers}:{nodes}");
        }
        for d in [d_node, d_local, d_global] {
            if !d.is_finite() || d < 0.0 {
                bail!("dragonfly distances must be finite and non-negative, got {d}");
            }
        }
        Ok(Dragonfly { groups, routers, nodes, d_node, d_local, d_global })
    }

    /// Parse the spec body `G:R:N` or `G:R:N/d_node,d_local,d_global`
    /// (e.g. `8:4:4/1,2,5`).
    pub fn parse(rest: &str) -> Result<Dragonfly> {
        let (dims_s, d_s) = match rest.split_once('/') {
            Some((a, b)) => (a, Some(b)),
            None => (rest, None),
        };
        let dims: Vec<u32> = dims_s
            .split(':')
            .map(|t| t.trim().parse::<u32>().map_err(Into::into))
            .collect::<Result<_>>()
            .with_context(|| format!("dragonfly dims `{dims_s}` (want G:R:N)"))?;
        let [groups, routers, nodes] = dims[..] else {
            bail!("dragonfly dims `{dims_s}` want exactly G:R:N");
        };
        let (d_node, d_local, d_global) = match d_s {
            Some(d) => {
                let ds: Vec<f64> = d
                    .split(',')
                    .map(|t| t.trim().parse::<f64>().map_err(Into::into))
                    .collect::<Result<_>>()
                    .with_context(|| format!("dragonfly distances `{d}`"))?;
                let [dn, dl, dg] = ds[..] else {
                    bail!("dragonfly distances `{d}` want exactly d_node,d_local,d_global");
                };
                (dn, dl, dg)
            }
            None => (1.0, 2.0, 5.0),
        };
        Dragonfly::new(groups, routers, nodes, d_node, d_local, d_global)
    }
}

impl MachineModel for Dragonfly {
    fn k(&self) -> usize {
        self.groups as usize * self.routers as usize * self.nodes as usize
    }

    fn distance(&self, x: Block, y: Block) -> f64 {
        if x == y {
            return 0.0;
        }
        if x / self.nodes == y / self.nodes {
            return self.d_node;
        }
        let per_group = self.nodes * self.routers;
        if x / per_group == y / per_group {
            self.d_local
        } else {
            self.d_global
        }
    }

    fn section_schedule(&self) -> Vec<u32> {
        vec![self.nodes, self.routers, self.groups]
    }

    fn label(&self) -> String {
        format!("dragonfly:{}:{}:{}", self.groups, self.routers, self.nodes)
    }

    fn spec_string(&self) -> String {
        format!("{}/{},{},{}", self.label(), self.d_node, self.d_local, self.d_global)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_tier_distances() {
        let d = Dragonfly::parse("4:4:2/1,2,5").unwrap();
        assert_eq!(d.k(), 32);
        assert_eq!(d.distance(0, 0), 0.0);
        assert_eq!(d.distance(0, 1), 1.0); // same router
        assert_eq!(d.distance(0, 2), 2.0); // same group, other router
        assert_eq!(d.distance(0, 8), 5.0); // other group
        assert_eq!(d.section_schedule(), vec![2, 4, 4]);
    }

    #[test]
    fn defaults_are_hop_counts() {
        let d = Dragonfly::parse("2:2:2").unwrap();
        assert_eq!(d.distance(0, 1), 1.0);
        assert_eq!(d.distance(0, 2), 2.0);
        assert_eq!(d.distance(0, 4), 5.0);
        assert_eq!(d.spec_string(), "dragonfly:2:2:2/1,2,5");
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(Dragonfly::parse("4:4").is_err());
        assert!(Dragonfly::parse("4:0:2").is_err());
        assert!(Dragonfly::parse("4:4:2/1,2").is_err());
        assert!(Dragonfly::parse("4:4:2/1,2,nan").is_err());
        assert!(Dragonfly::parse("4:4:2/1,-2,5").is_err());
    }
}
