//! k-dimensional torus / mesh machine model — hop (Manhattan) distance.

use super::MachineModel;
use crate::Block;
use anyhow::{bail, Context, Result};

/// A `d_1 × d_2 × … × d_n` grid of PEs. `wrap = true` is a torus (each
/// dimension wraps around), `wrap = false` a mesh. PE ids are mixed-radix
/// with the **first** dimension fastest — exactly the numbering the
/// multisection schedule implies, so sectioning at the outermost level
/// splits the machine into contiguous hyperplanes of the last dimension.
///
/// `distance(x, y) = link_w · Σ_i hop(x_i, y_i)` with
/// `hop(a, b) = min(|a−b|, d_i − |a−b|)` on a torus and `|a−b|` on a mesh.
#[derive(Clone, Debug)]
pub struct Torus {
    dims: Vec<u32>,
    wrap: bool,
    link_w: f64,
}

impl Torus {
    pub fn new(dims: Vec<u32>, wrap: bool, link_w: f64) -> Result<Torus> {
        if dims.is_empty() {
            bail!("torus/mesh needs at least one dimension");
        }
        if dims.iter().any(|&d| d == 0) {
            bail!("torus/mesh dimensions must be positive, got {dims:?}");
        }
        if !link_w.is_finite() || link_w <= 0.0 {
            bail!("torus/mesh link weight must be positive and finite, got {link_w}");
        }
        Ok(Torus { dims, wrap, link_w })
    }

    /// Parse the spec body `4x4x4` or `4x4x4/2.5` (per-hop link weight).
    pub fn parse(rest: &str, wrap: bool) -> Result<Torus> {
        let (dims_s, w_s) = match rest.split_once('/') {
            Some((a, b)) => (a, Some(b)),
            None => (rest, None),
        };
        let dims: Vec<u32> = dims_s
            .split('x')
            .map(|t| t.trim().parse::<u32>().map_err(Into::into))
            .collect::<Result<_>>()
            .with_context(|| format!("torus/mesh dims `{dims_s}` (want e.g. 4x4x4)"))?;
        let link_w = match w_s {
            Some(w) => w.trim().parse::<f64>().with_context(|| format!("link weight `{w}`"))?,
            None => 1.0,
        };
        Torus::new(dims, wrap, link_w)
    }

    fn scheme(&self) -> &'static str {
        if self.wrap {
            "torus"
        } else {
            "mesh"
        }
    }

    fn dims_string(&self) -> String {
        self.dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
    }
}

impl MachineModel for Torus {
    fn k(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }

    fn distance(&self, x: Block, y: Block) -> f64 {
        if x == y {
            return 0.0;
        }
        let (mut x, mut y) = (x as usize, y as usize);
        let mut hops = 0usize;
        for &d in &self.dims {
            let d = d as usize;
            let diff = (x % d).abs_diff(y % d);
            hops += if self.wrap { diff.min(d - diff) } else { diff };
            x /= d;
            y /= d;
        }
        self.link_w * hops as f64
    }

    fn section_schedule(&self) -> Vec<u32> {
        self.dims.clone()
    }

    fn label(&self) -> String {
        format!("{}:{}", self.scheme(), self.dims_string())
    }

    fn spec_string(&self) -> String {
        if self.link_w == 1.0 {
            self.label()
        } else {
            format!("{}/{}", self.label(), self.link_w)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_distances_wrap() {
        let t = Torus::parse("8", true).unwrap();
        assert_eq!(t.k(), 8);
        assert_eq!(t.distance(0, 1), 1.0);
        assert_eq!(t.distance(0, 4), 4.0);
        assert_eq!(t.distance(0, 7), 1.0); // wraps
        assert_eq!(t.distance(0, 0), 0.0);
    }

    #[test]
    fn mesh_does_not_wrap() {
        let m = Torus::parse("8", false).unwrap();
        assert_eq!(m.distance(0, 7), 7.0);
    }

    #[test]
    fn torus3d_manhattan_hops() {
        let t = Torus::parse("4x4x4", true).unwrap();
        assert_eq!(t.k(), 64);
        // Neighbors along each axis: id = x + 4y + 16z.
        assert_eq!(t.distance(0, 1), 1.0);
        assert_eq!(t.distance(0, 4), 1.0);
        assert_eq!(t.distance(0, 16), 1.0);
        // Opposite corner: 2 hops per axis (wrap).
        assert_eq!(t.distance(0, 63), 6.0);
        // Wrap along x: 3 → 0 is one hop.
        assert_eq!(t.distance(3, 0), 1.0);
    }

    #[test]
    fn link_weight_scales() {
        let t = Torus::parse("4x4/2.5", true).unwrap();
        assert_eq!(t.distance(0, 1), 2.5);
        assert_eq!(t.spec_string(), "torus:4x4/2.5");
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(Torus::parse("", true).is_err());
        assert!(Torus::parse("4x0", true).is_err());
        assert!(Torus::parse("4x4/-1", true).is_err());
        assert!(Torus::parse("4x4/nan", true).is_err());
        assert!(Torus::parse("4xbanana", true).is_err());
    }
}
