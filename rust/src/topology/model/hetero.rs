//! Heterogeneous machine model — uneven node sizes (hostfile-style).

use super::MachineModel;
use crate::Block;
use anyhow::{bail, Context, Result};

/// A cluster of nodes with *uneven* PE counts — the shape a hostfile
/// (`node0 slots=4`, `node1 slots=8`, …) describes. PE ids are assigned
/// consecutively per node; `distance` is `d_intra` within a node and
/// `d_inter` across nodes.
///
/// Uneven fan-outs cannot feed a uniform multisection schedule, so
/// [`section_schedule`](MachineModel::section_schedule) is the flat
/// `[k]`: the hierarchical solvers do a single `k`-way partition and the
/// model's distances steer refinement toward co-locating traffic on the
/// big nodes.
#[derive(Clone, Debug)]
pub struct HeteroNodes {
    sizes: Vec<u32>,
    d_intra: f64,
    d_inter: f64,
    /// PE → node index (O(1) distance lookups).
    node_of: Vec<u32>,
}

impl HeteroNodes {
    pub fn new(sizes: Vec<u32>, d_intra: f64, d_inter: f64) -> Result<HeteroNodes> {
        if sizes.is_empty() {
            bail!("hetero machine needs at least one node");
        }
        if sizes.iter().any(|&s| s == 0) {
            bail!("hetero node sizes must be positive, got {sizes:?}");
        }
        for d in [d_intra, d_inter] {
            if !d.is_finite() || d < 0.0 {
                bail!("hetero distances must be finite and non-negative, got {d}");
            }
        }
        let mut node_of = Vec::with_capacity(sizes.iter().map(|&s| s as usize).sum());
        for (i, &s) in sizes.iter().enumerate() {
            node_of.resize(node_of.len() + s as usize, i as u32);
        }
        Ok(HeteroNodes { sizes, d_intra, d_inter, node_of })
    }

    /// Parse the spec body `S1+S2+…` or `S1+S2+…/d_intra,d_inter`
    /// (e.g. `4+8+4/1,10`). Defaults: `d_intra = 1`, `d_inter = 10`.
    pub fn parse(rest: &str) -> Result<HeteroNodes> {
        let (sizes_s, d_s) = match rest.split_once('/') {
            Some((a, b)) => (a, Some(b)),
            None => (rest, None),
        };
        let sizes: Vec<u32> = sizes_s
            .split('+')
            .map(|t| t.trim().parse::<u32>().map_err(Into::into))
            .collect::<Result<_>>()
            .with_context(|| format!("hetero node sizes `{sizes_s}` (want e.g. 4+8+4)"))?;
        let (d_intra, d_inter) = match d_s {
            Some(d) => {
                let ds: Vec<f64> = d
                    .split(',')
                    .map(|t| t.trim().parse::<f64>().map_err(Into::into))
                    .collect::<Result<_>>()
                    .with_context(|| format!("hetero distances `{d}`"))?;
                let [di, dx] = ds[..] else {
                    bail!("hetero distances `{d}` want exactly d_intra,d_inter");
                };
                (di, dx)
            }
            None => (1.0, 10.0),
        };
        HeteroNodes::new(sizes, d_intra, d_inter)
    }
}

impl MachineModel for HeteroNodes {
    fn k(&self) -> usize {
        self.node_of.len()
    }

    fn distance(&self, x: Block, y: Block) -> f64 {
        if x == y {
            return 0.0;
        }
        if self.node_of[x as usize] == self.node_of[y as usize] {
            self.d_intra
        } else {
            self.d_inter
        }
    }

    fn section_schedule(&self) -> Vec<u32> {
        vec![self.node_of.len() as u32]
    }

    fn label(&self) -> String {
        let s: Vec<String> = self.sizes.iter().map(|x| x.to_string()).collect();
        format!("hetero:{}", s.join("+"))
    }

    fn spec_string(&self) -> String {
        format!("{}/{},{}", self.label(), self.d_intra, self.d_inter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uneven_nodes_two_tier_distance() {
        let h = HeteroNodes::parse("4+8+4/1,10").unwrap();
        assert_eq!(h.k(), 16);
        assert_eq!(h.distance(0, 3), 1.0); // both on node 0
        assert_eq!(h.distance(0, 4), 10.0); // node 0 vs node 1
        assert_eq!(h.distance(4, 11), 1.0); // both on the big node
        assert_eq!(h.distance(11, 12), 10.0);
        assert_eq!(h.distance(5, 5), 0.0);
    }

    #[test]
    fn flat_schedule() {
        let h = HeteroNodes::parse("4+8+4").unwrap();
        assert_eq!(h.section_schedule(), vec![16]);
        assert_eq!(h.spec_string(), "hetero:4+8+4/1,10");
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(HeteroNodes::parse("").is_err());
        assert!(HeteroNodes::parse("4+0").is_err());
        assert!(HeteroNodes::parse("4+4/1").is_err());
        assert!(HeteroNodes::parse("4+4/1,nan").is_err());
        assert!(HeteroNodes::parse("4+4/-1,10").is_err());
    }
}
