//! Explicit distance-matrix machine model (`file:PATH`).

use super::MachineModel;
use crate::Block;
use anyhow::{bail, Context, Result};

/// Largest `k` a matrix file may declare — the model stores the full
/// `k × k` table, so this caps memory at ~0.5 GiB.
pub const FILE_K_MAX: usize = 8192;

/// A machine described by an explicit `k × k` distance table.
///
/// File format (whitespace tolerant, `#` comments):
///
/// ```text
/// # k
/// 4
/// 0 1 10 10
/// 1 0 10 10
/// 10 10 0 1
/// 10 10 1 0
/// ```
///
/// The table must be finite, non-negative, symmetric and zero on the
/// diagonal. The schedule is the flat `[k]` (an arbitrary matrix carries
/// no hierarchy), so solvers do one `k`-way partition and let the
/// distances steer refinement.
#[derive(Clone, Debug)]
pub struct MatrixModel {
    k: usize,
    m: Vec<f64>,
    /// Where the matrix came from (`file:SOURCE` round trip).
    source: String,
    /// FNV-1a over `k` and the table bits — two models with the same
    /// source label but different tables must not compare equal.
    digest: u64,
    /// True when loaded from a real path (`from_path`), so the spec
    /// string round-trips on any host that has the file.
    from_disk: bool,
}

impl MatrixModel {
    /// Parse the file format from a string; `source` names it for labels
    /// and the spec round trip.
    pub fn from_text(text: &str, source: impl Into<String>) -> Result<MatrixModel> {
        let mut tokens = text
            .lines()
            .map(|l| l.split('#').next().unwrap_or(""))
            .flat_map(|l| l.split_whitespace());
        let k: usize = tokens
            .next()
            .context("distance-matrix file is empty (want k, then k×k values)")?
            .parse()
            .context("first value must be k")?;
        if k == 0 {
            bail!("distance-matrix file declares k = 0");
        }
        if k > FILE_K_MAX {
            bail!("distance-matrix file declares k = {k} > {FILE_K_MAX} (dense storage cap)");
        }
        let mut m = Vec::with_capacity(k * k);
        for tok in tokens.by_ref().take(k * k) {
            m.push(tok.parse::<f64>().with_context(|| format!("bad distance value `{tok}`"))?);
        }
        if m.len() != k * k {
            bail!("distance-matrix file has {} values, want k² = {}", m.len(), k * k);
        }
        if tokens.next().is_some() {
            bail!("distance-matrix file has trailing values after k² entries");
        }
        for x in 0..k {
            for y in 0..k {
                let v = m[x * k + y];
                if !v.is_finite() || v < 0.0 {
                    bail!("distance[{x},{y}] = {v} must be finite and non-negative");
                }
                if x == y && v != 0.0 {
                    bail!("distance[{x},{x}] = {v} must be zero on the diagonal");
                }
                if (v - m[y * k + x]).abs() > 1e-9 * v.abs().max(1.0) {
                    bail!("distance matrix is not symmetric at ({x},{y})");
                }
            }
        }
        let mut digest = 0xcbf2_9ce4_8422_2325u64; // FNV-1a
        let mut mix = |bytes: &[u8]| {
            for &b in bytes {
                digest ^= b as u64;
                digest = digest.wrapping_mul(0x1000_0000_01b3);
            }
        };
        mix(&(k as u64).to_le_bytes());
        for v in &m {
            mix(&v.to_bits().to_le_bytes());
        }
        Ok(MatrixModel { k, m, source: source.into(), digest, from_disk: false })
    }

    /// Load `file:PATH` from disk.
    pub fn from_path(path: &str) -> Result<MatrixModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read distance-matrix file {path}"))?;
        let mut model = Self::from_text(&text, path)?;
        model.from_disk = true;
        Ok(model)
    }
}

impl MachineModel for MatrixModel {
    fn k(&self) -> usize {
        self.k
    }

    fn distance(&self, x: Block, y: Block) -> f64 {
        self.m[x as usize * self.k + y as usize]
    }

    fn section_schedule(&self) -> Vec<u32> {
        vec![self.k as u32]
    }

    fn label(&self) -> String {
        format!("file:{}(k={})", self.source, self.k)
    }

    fn spec_string(&self) -> String {
        format!("file:{}", self.source)
    }

    fn fingerprint(&self) -> u64 {
        self.digest
    }

    fn spec_round_trips(&self) -> bool {
        // An in-memory table has no path another host could re-read.
        self.from_disk
    }

    fn lookup_is_table(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "# two nodes of two PEs\n4\n0 1 10 10\n1 0 10 10\n10 10 0 1\n10 10 1 0\n";

    #[test]
    fn parses_and_looks_up() {
        let m = MatrixModel::from_text(GOOD, "test").unwrap();
        assert_eq!(m.k(), 4);
        assert_eq!(m.distance(0, 1), 1.0);
        assert_eq!(m.distance(0, 2), 10.0);
        assert_eq!(m.distance(3, 3), 0.0);
        assert_eq!(m.section_schedule(), vec![4]);
    }

    #[test]
    fn round_trips_through_a_real_file() {
        let path = std::env::temp_dir().join(format!("heipa_dist_{}.mat", std::process::id()));
        std::fs::write(&path, GOOD).unwrap();
        let m = MatrixModel::from_path(path.to_str().unwrap()).unwrap();
        assert_eq!(m.k(), 4);
        assert_eq!(m.spec_string(), format!("file:{}", path.display()));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn same_label_different_tables_have_different_fingerprints() {
        let a = MatrixModel::from_text("2\n0 1\n1 0", "inline").unwrap();
        let b = MatrixModel::from_text("2\n0 5\n5 0", "inline").unwrap();
        assert_eq!(a.spec_string(), b.spec_string());
        assert_ne!(a.fingerprint(), b.fingerprint());
        let ma = crate::topology::Machine::from_model(a).unwrap();
        let mb = crate::topology::Machine::from_model(b).unwrap();
        assert_ne!(ma, mb, "distinct tables must not compare equal");
        let a2 = MatrixModel::from_text("2\n0 1\n1 0", "inline").unwrap();
        assert_eq!(crate::topology::Machine::from_model(a2).unwrap(), ma);
    }

    #[test]
    fn rejects_malformed_tables() {
        // Wrong count.
        assert!(MatrixModel::from_text("2\n0 1 1", "t").is_err());
        // Trailing junk.
        assert!(MatrixModel::from_text("1\n0\n7", "t").is_err());
        // Asymmetric.
        assert!(MatrixModel::from_text("2\n0 1\n2 0", "t").is_err());
        // Nonzero diagonal.
        assert!(MatrixModel::from_text("2\n1 1\n1 0", "t").is_err());
        // NaN / negative.
        assert!(MatrixModel::from_text("2\n0 nan\nnan 0", "t").is_err());
        assert!(MatrixModel::from_text("2\n0 -1\n-1 0", "t").is_err());
        // Empty.
        assert!(MatrixModel::from_text("# nothing\n", "t").is_err());
    }
}
