//! Pluggable machine models — the subsystem behind the `topology=` spec
//! key.
//!
//! The paper defines process mapping against a homogeneous hierarchy
//! `a_1:…:a_ℓ / d_1:…:d_ℓ`, but real targets are tori, fat-trees,
//! dragonflies and heterogeneous node mixes. Every one of them is a
//! [`MachineModel`]; the cheap-to-clone [`Machine`] handle is what the
//! engine, the solvers and the refinement hot loops consume.
//!
//! Spec strings (see [`parse_topology`]):
//!
//! | scheme | example | model |
//! |---|---|---|
//! | `hier` | `hier:4:8:6/1:10:100` | homogeneous [`Hierarchy`] |
//! | `torus` | `torus:4x4x4` / `torus:8x8/2.5` | wrap-around grid, hop distance |
//! | `mesh` | `mesh:16x16` | grid without wrap-around |
//! | `fattree` | `fattree:3:2,16,48/1,5,20` | fat-tree, per-level link weights |
//! | `dragonfly` | `dragonfly:8:4:4/1,2,5` | group/router/node |
//! | `hetero` | `hetero:4+8+4/1,10` | uneven node sizes (hostfile-style) |
//! | `file` | `file:dist.mat` | explicit distance matrix |

pub mod dragonfly;
pub mod fattree;
pub mod filemat;
pub mod hetero;
pub mod oracle;
pub mod torus;

pub use dragonfly::Dragonfly;
pub use fattree::FatTree;
pub use filemat::MatrixModel;
pub use hetero::HeteroNodes;
pub use oracle::{DistanceOracle, OracleRow, DENSE_K_MAX};
pub use torus::Torus;

use super::Hierarchy;
use crate::Block;
use anyhow::{bail, Context, Result};
use std::fmt;
use std::sync::Arc;

/// A machine model: `k` PEs, a pairwise distance function, and a
/// hierarchy-section schedule for multisection.
///
/// ## How multisection consumes the level schedule
///
/// [`section_schedule`](MachineModel::section_schedule) returns fan-outs
/// `a_1 … a_ℓ` **innermost-first** with `Π a_i = k`. The hierarchical
/// multisection solvers (`gpu_hm`, `sharedmap`) recurse outermost-first:
/// at level `i = ℓ, ℓ−1, …, 1` they partition the current subgraph into
/// `a_i` blocks and assign each block the contiguous PE range
/// `[off + b·span, off + (b+1)·span)` with `span = Π_{j<i} a_j` — i.e.
/// PE ids are mixed-radix in the schedule with `a_1` fastest, and
/// [`distance`](MachineModel::distance) must agree with that numbering.
/// Models whose structure is irregular (uneven node sizes, arbitrary
/// matrix files) return the flat schedule `[k]`: multisection then
/// degenerates to a single `k`-way partition and the model's distances
/// steer refinement instead.
///
/// Distances must be finite, non-negative, symmetric, and zero on the
/// diagonal; implementations validate this at construction (tested by
/// the oracle-parity suite in `tests/models.rs`).
pub trait MachineModel: fmt::Debug + Send + Sync {
    /// Total number of PEs.
    fn k(&self) -> usize;

    /// Distance factor `D_xy` between PEs `x` and `y` — the implicit
    /// oracle: O(ℓ) for hierarchical models, O(dim) for tori, O(1) for
    /// table-backed models. Never materializes anything.
    fn distance(&self, x: Block, y: Block) -> f64;

    /// Innermost-first fan-outs for hierarchical multisection (see the
    /// trait docs). Must multiply to `k`.
    fn section_schedule(&self) -> Vec<u32>;

    /// Human-readable label (CSV rows, progress lines).
    fn label(&self) -> String;

    /// Canonical `topology=` spec string; `parse_topology(spec_string())`
    /// reconstructs an equivalent model (wire-protocol round trip).
    fn spec_string(&self) -> String;

    /// The underlying homogeneous hierarchy, when this model is one.
    fn as_hierarchy(&self) -> Option<&Hierarchy> {
        None
    }

    /// Structural fingerprint for [`Machine`] equality. Models fully
    /// determined by their spec string keep the default `0`; models with
    /// out-of-band content (e.g. a distance table loaded from a file or
    /// built in memory) must hash that content here, so two machines
    /// with the same label but different tables never compare equal.
    fn fingerprint(&self) -> u64 {
        0
    }

    /// Does `parse_topology(spec_string())` reconstruct an equivalent
    /// model on *any* host? `false` for models whose content lives only
    /// in this process (e.g. a [`MatrixModel`] built from an in-memory
    /// string) — such machines must not be lifted onto the wire.
    fn spec_round_trips(&self) -> bool {
        true
    }

    /// Is [`distance`](MachineModel::distance) already an O(1) table
    /// lookup? Oracles then skip dense materialization and row caching —
    /// both would only duplicate the model's own table.
    fn lookup_is_table(&self) -> bool {
        false
    }
}

impl MachineModel for Hierarchy {
    fn k(&self) -> usize {
        Hierarchy::k(self)
    }

    fn distance(&self, x: Block, y: Block) -> f64 {
        Hierarchy::distance(self, x, y)
    }

    fn section_schedule(&self) -> Vec<u32> {
        self.a.clone()
    }

    fn label(&self) -> String {
        Hierarchy::label(self)
    }

    fn spec_string(&self) -> String {
        format!("hier:{}", Hierarchy::label(self))
    }

    fn as_hierarchy(&self) -> Option<&Hierarchy> {
        Some(self)
    }
}

/// Shared, cheap-to-clone handle to a validated [`MachineModel`] — the
/// machine-side argument of every solver, metric and refinement pass.
/// Construction validates the section schedule once and caches it.
#[derive(Clone)]
pub struct Machine {
    model: Arc<dyn MachineModel>,
    schedule: Arc<[u32]>,
    k: usize,
}

impl fmt::Debug for Machine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Machine({})", self.model.spec_string())
    }
}

impl PartialEq for Machine {
    fn eq(&self, other: &Self) -> bool {
        self.model.spec_string() == other.model.spec_string()
            && self.model.fingerprint() == other.model.fingerprint()
    }
}

impl From<Hierarchy> for Machine {
    fn from(h: Hierarchy) -> Machine {
        // A constructed Hierarchy always has a consistent schedule.
        Machine::new(Arc::new(h)).expect("hierarchy is a valid machine model")
    }
}

impl Machine {
    /// Wrap and validate a model: `k ≥ 1` and a positive schedule whose
    /// product equals `k`.
    pub fn new(model: Arc<dyn MachineModel>) -> Result<Machine> {
        let k = model.k();
        if k == 0 {
            bail!("machine model `{}` has zero PEs", model.label());
        }
        let schedule = model.section_schedule();
        if schedule.is_empty() || schedule.iter().any(|&a| a == 0) {
            bail!("machine model `{}` has an empty or zero section schedule", model.label());
        }
        let prod: usize = schedule.iter().map(|&a| a as usize).product();
        if prod != k {
            bail!(
                "machine model `{}`: section schedule {:?} multiplies to {prod}, but k = {k}",
                model.label(),
                schedule
            );
        }
        Ok(Machine { k, schedule: schedule.into(), model })
    }

    /// [`Machine::new`] for an owned model value.
    pub fn from_model<M: MachineModel + 'static>(model: M) -> Result<Machine> {
        Machine::new(Arc::new(model))
    }

    /// Homogeneous hierarchy from the classic two-string form
    /// (`"4:8:6"`, `"1:10:100"`).
    pub fn hier(hier: &str, dist: &str) -> Result<Machine> {
        Machine::new(Arc::new(Hierarchy::parse(hier, dist)?))
    }

    /// Parse a `topology=` spec string (see [`parse_topology`]).
    pub fn parse_spec(spec: &str) -> Result<Machine> {
        parse_topology(spec)
    }

    /// The one resolution rule every front-end shares: a `topology` spec
    /// string wins when present, the `hierarchy`/`distance` pair
    /// otherwise. (`MapSpec::machine`, `RunConfig::machine` and the CLI
    /// all call this, so precedence can never diverge between them.)
    pub fn resolve(topology: Option<&str>, hier: &str, dist: &str) -> Result<Machine> {
        match topology {
            Some(spec) => Machine::parse_spec(spec),
            None => Machine::hier(hier, dist),
        }
    }

    /// See [`MachineModel::spec_round_trips`].
    pub fn spec_round_trips(&self) -> bool {
        self.model.spec_round_trips()
    }

    /// See [`MachineModel::lookup_is_table`].
    pub fn lookup_is_table(&self) -> bool {
        self.model.lookup_is_table()
    }

    /// Total number of PEs.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of multisection levels (length of the schedule).
    pub fn levels(&self) -> usize {
        self.schedule.len()
    }

    /// Innermost-first section schedule (see [`MachineModel`] docs).
    pub fn schedule(&self) -> &[u32] {
        &self.schedule
    }

    /// PE span of one block when sectioning at `level` (1-based from the
    /// innermost): `Π_{j<level} a_j`. Panics on level 0 or past `ℓ`.
    pub fn pes_per_block_at_level(&self, level: usize) -> usize {
        assert!(
            (1..=self.schedule.len()).contains(&level),
            "pes_per_block_at_level: level {level} out of range 1..={} (levels are 1-based)",
            self.schedule.len()
        );
        self.schedule[..level - 1].iter().map(|&x| x as usize).product()
    }

    /// Distance factor `D_xy` via the model's implicit oracle.
    #[inline]
    pub fn distance(&self, x: Block, y: Block) -> f64 {
        debug_assert!(
            (x as usize) < self.k && (y as usize) < self.k,
            "PE id out of range: distance({x}, {y}) on a k={} machine",
            self.k
        );
        self.model.distance(x, y)
    }

    pub fn label(&self) -> String {
        self.model.label()
    }

    /// Canonical `topology=` spec string (wire round trip).
    pub fn spec_string(&self) -> String {
        self.model.spec_string()
    }

    /// The wrapped model.
    pub fn model(&self) -> &dyn MachineModel {
        &*self.model
    }

    /// The underlying homogeneous hierarchy, when this machine is one.
    pub fn as_hierarchy(&self) -> Option<&Hierarchy> {
        self.model.as_hierarchy()
    }

    /// General-purpose oracle: dense rows for small machines, the blocked
    /// row cache beyond [`DENSE_K_MAX`] (serial QAP-style scans).
    pub fn oracle(&self) -> DistanceOracle {
        DistanceOracle::auto(self)
    }

    /// Refinement-flavor oracle: dense rows for small machines, the
    /// lock-free implicit oracle beyond [`DENSE_K_MAX`] (parallel gain
    /// kernels must not contend on a row-cache lock).
    pub fn oracle_for_refine(&self) -> DistanceOracle {
        DistanceOracle::for_refine(self)
    }

    /// Materialized `k × k` matrix (device uploads, small `k` only).
    pub fn dense_matrix(&self) -> super::DistanceMatrix {
        super::DistanceMatrix::from_fn(self.k, |x, y| self.model.distance(x, y))
    }
}

/// The spec schemes [`parse_topology`] understands.
pub fn known_schemes() -> [&'static str; 7] {
    ["hier", "torus", "mesh", "fattree", "dragonfly", "hetero", "file"]
}

/// Parse a `topology=` spec string into a [`Machine`].
///
/// * `hier:4:8:6/1:10:100` — homogeneous hierarchy
/// * `torus:4x4x4[/W]` — k-dim torus, hop distance × link weight `W`
/// * `mesh:16x16[/W]` — k-dim mesh (no wrap-around)
/// * `fattree:[L:]A1,…,AL/W1,…,WL` — fat-tree arities + per-level link
///   weights (cost = 2·Σ of the climbed links)
/// * `dragonfly:G:R:N[/d_node,d_local,d_global]` — groups × routers ×
///   nodes
/// * `hetero:S1+S2+…[/d_intra,d_inter]` — heterogeneous node sizes
/// * `file:PATH` — explicit distance matrix file
pub fn parse_topology(spec: &str) -> Result<Machine> {
    let spec = spec.trim();
    let Some((scheme, rest)) = spec.split_once(':') else {
        bail!(
            "topology spec `{spec}` needs a `scheme:` prefix (known schemes: {})",
            known_schemes().join(", ")
        );
    };
    match scheme {
        "hier" => {
            let (a, d) = rest
                .split_once('/')
                .with_context(|| format!("hier spec `{rest}` wants A1:…:AL/D1:…:DL"))?;
            Machine::hier(a, d)
        }
        "torus" => Machine::from_model(Torus::parse(rest, true)?),
        "mesh" => Machine::from_model(Torus::parse(rest, false)?),
        "fattree" => Machine::from_model(FatTree::parse(rest)?),
        "dragonfly" => Machine::from_model(Dragonfly::parse(rest)?),
        "hetero" => Machine::from_model(HeteroNodes::parse(rest)?),
        "file" => Machine::from_model(MatrixModel::from_path(rest)?),
        other => bail!(
            "unknown topology scheme `{other}` (known schemes: {})",
            known_schemes().join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchy_is_a_machine_model() {
        let m = Machine::hier("4:8:6", "1:10:100").unwrap();
        assert_eq!(m.k(), 192);
        assert_eq!(m.levels(), 3);
        assert_eq!(m.schedule(), &[4, 8, 6]);
        assert_eq!(m.distance(0, 3), 1.0);
        assert_eq!(m.distance(0, 4), 10.0);
        assert_eq!(m.distance(0, 191), 100.0);
        assert!(m.as_hierarchy().is_some());
        assert_eq!(m.pes_per_block_at_level(3), 32);
    }

    #[test]
    fn parse_registry_covers_every_scheme() {
        for spec in [
            "hier:4:8:2/1:10:100",
            "torus:4x4x4",
            "mesh:8x8",
            "fattree:3:2,4,4/1,5,20",
            "dragonfly:4:4:2/1,2,5",
            "hetero:4+8+4/1,10",
        ] {
            let m = parse_topology(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert!(m.k() > 0, "{spec}");
            // Round trip: the canonical spec string parses to an equal machine.
            let m2 = parse_topology(&m.spec_string()).unwrap();
            assert_eq!(m, m2, "{spec} round trip");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_topology("nope:1:2").is_err());
        assert!(parse_topology("justastring").is_err());
        assert!(parse_topology("hier:4:8:2").is_err()); // missing /distances
        assert!(parse_topology("torus:0x4").is_err());
        assert!(parse_topology("file:/no/such/heipa/file").is_err());
    }

    #[test]
    fn schedule_product_matches_k_for_all_models() {
        for spec in
            ["hier:4:8:2/1:10:100", "torus:3x5", "fattree:2,4/1,5", "dragonfly:2:3:4", "hetero:3+5"]
        {
            let m = parse_topology(spec).unwrap();
            let prod: usize = m.schedule().iter().map(|&a| a as usize).product();
            assert_eq!(prod, m.k(), "{spec}");
        }
    }

    #[test]
    fn machine_equality_is_by_spec() {
        let a = Machine::hier("4:8:2", "1:10:100").unwrap();
        let b = parse_topology("hier:4:8:2/1:10:100").unwrap();
        let c = parse_topology("torus:4x4x4").unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
