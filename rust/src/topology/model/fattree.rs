//! Fat-tree machine model — per-level link weights, up-and-down cost.

use super::MachineModel;
use crate::Block;
use anyhow::{bail, Context, Result};

/// A fat-tree with `L` switch levels: `arity[0]` PEs per edge switch,
/// `arity[1]` edge switches per level-2 switch, … PE ids are mixed-radix
/// with `arity[0]` fastest (the multisection numbering).
///
/// A message between PEs whose lowest common switch sits at level `i`
/// climbs the links of levels `1..=i` on the way up and again on the way
/// down: `distance = 2 · Σ_{j=1..i} link_w[j−1]`. Unlike the flat
/// per-level `d_i` of a [`crate::topology::Hierarchy`], the cost
/// *accumulates* along the path, which is how fat-tree latency behaves.
#[derive(Clone, Debug)]
pub struct FatTree {
    arity: Vec<u32>,
    link_w: Vec<f64>,
}

impl FatTree {
    pub fn new(arity: Vec<u32>, link_w: Vec<f64>) -> Result<FatTree> {
        if arity.is_empty() || arity.len() != link_w.len() {
            bail!("fat-tree arities and link weights must be non-empty and equal length");
        }
        if arity.iter().any(|&a| a == 0) {
            bail!("fat-tree arities must be positive, got {arity:?}");
        }
        if link_w.iter().any(|w| !w.is_finite() || *w < 0.0) {
            bail!("fat-tree link weights must be finite and non-negative, got {link_w:?}");
        }
        Ok(FatTree { arity, link_w })
    }

    /// Parse the spec body `A1,…,AL/W1,…,WL`, optionally prefixed with a
    /// redundant level count: `L:A1,…,AL/W1,…,WL` (e.g.
    /// `3:2,16,48/1,5,20`). A declared `L` must match the list length.
    pub fn parse(rest: &str) -> Result<FatTree> {
        let (declared, body) = match rest.split_once(':') {
            Some((head, tail)) => (
                Some(
                    head.trim()
                        .parse::<usize>()
                        .with_context(|| format!("fat-tree level count `{head}`"))?,
                ),
                tail,
            ),
            None => (None, rest),
        };
        let (a_s, w_s) = body
            .split_once('/')
            .with_context(|| format!("fat-tree spec `{body}` wants A1,…,AL/W1,…,WL"))?;
        let arity: Vec<u32> = a_s
            .split(',')
            .map(|t| t.trim().parse::<u32>().map_err(Into::into))
            .collect::<Result<_>>()
            .with_context(|| format!("fat-tree arities `{a_s}`"))?;
        let link_w: Vec<f64> = w_s
            .split(',')
            .map(|t| t.trim().parse::<f64>().map_err(Into::into))
            .collect::<Result<_>>()
            .with_context(|| format!("fat-tree link weights `{w_s}`"))?;
        if let Some(l) = declared {
            if l != arity.len() {
                bail!("fat-tree declares {l} levels but lists {} arities", arity.len());
            }
        }
        FatTree::new(arity, link_w)
    }
}

impl MachineModel for FatTree {
    fn k(&self) -> usize {
        self.arity.iter().map(|&a| a as usize).product()
    }

    fn distance(&self, x: Block, y: Block) -> f64 {
        if x == y {
            return 0.0;
        }
        let (mut x, mut y) = (x, y);
        let mut cost = 0.0;
        for (i, &a) in self.arity.iter().enumerate() {
            // Climb one level on both sides.
            cost += 2.0 * self.link_w[i];
            x /= a;
            y /= a;
            if x == y {
                break;
            }
        }
        cost
    }

    fn section_schedule(&self) -> Vec<u32> {
        self.arity.clone()
    }

    fn label(&self) -> String {
        self.spec_string()
    }

    fn spec_string(&self) -> String {
        let a: Vec<String> = self.arity.iter().map(|x| x.to_string()).collect();
        let w: Vec<String> = self.link_w.iter().map(|x| x.to_string()).collect();
        format!("fattree:{}/{}", a.join(","), w.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ft() -> FatTree {
        FatTree::parse("2,4,4/1,5,20").unwrap()
    }

    #[test]
    fn k_and_schedule() {
        let f = ft();
        assert_eq!(f.k(), 32);
        assert_eq!(f.section_schedule(), vec![2, 4, 4]);
    }

    #[test]
    fn path_cost_accumulates_up_and_down() {
        let f = ft();
        // Same edge switch (ids 0,1): 2·1.
        assert_eq!(f.distance(0, 1), 2.0);
        // Through the level-2 switch: 2·(1+5).
        assert_eq!(f.distance(0, 2), 12.0);
        // Through the core: 2·(1+5+20).
        assert_eq!(f.distance(0, 8), 52.0);
        assert_eq!(f.distance(0, 0), 0.0);
    }

    #[test]
    fn declared_level_count_is_checked() {
        assert!(FatTree::parse("3:2,4,4/1,5,20").is_ok());
        assert!(FatTree::parse("2:2,4,4/1,5,20").is_err());
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(FatTree::parse("2,4/1").is_err()); // length mismatch
        assert!(FatTree::parse("2,0/1,5").is_err());
        assert!(FatTree::parse("2,4/1,nan").is_err());
        assert!(FatTree::parse("2,4/1,-5").is_err());
        assert!(FatTree::parse("2,4").is_err()); // missing weights
    }
}
