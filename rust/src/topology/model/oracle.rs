//! Distance oracles — three ways to answer `D[x, y]` queries.
//!
//! * **Implicit** — delegate every pair to the model (O(ℓ)/O(dim) per
//!   query, zero memory). The only backend that scales to arbitrary `k`
//!   inside parallel kernels.
//! * **Dense** — the materialized `k × k` matrix (O(1) queries, O(k²)
//!   memory). Only ever built for `k ≤` [`DENSE_K_MAX`].
//! * **Blocked row cache** — slabs of [`SLAB_ROWS`] consecutive rows,
//!   computed on demand and kept in a bounded FIFO behind a mutex. Built
//!   for the QAP/polish hot loops, which repeatedly scan `D[x, ·]` for a
//!   few hot `x` but never need the whole matrix.

use super::Machine;
use crate::topology::DistanceMatrix;
use crate::Block;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

/// Largest machine for which a dense `k × k` matrix may be materialized
/// (4096² f64 = 128 MiB). Beyond this, oracles stay implicit or blocked —
/// the acceptance bar for supercomputer-scale machines.
pub const DENSE_K_MAX: usize = 4096;

/// Rows per cache slab (one distance computation fills a whole slab).
pub const SLAB_ROWS: usize = 8;

/// Default slab capacity of the blocked cache (`128 · 8 · k` doubles).
const DEFAULT_SLAB_CAP: usize = 128;

/// A distance oracle over one [`Machine`] — see the module docs for the
/// three backends. `Send + Sync`; share it by reference across kernels.
#[derive(Debug)]
pub struct DistanceOracle {
    machine: Machine,
    backend: Backend,
}

#[derive(Debug)]
enum Backend {
    Implicit,
    Dense(DistanceMatrix),
    Blocked(RowCache),
}

impl DistanceOracle {
    /// Pure pass-through to the model (no memory, no locks).
    pub fn implicit(machine: &Machine) -> DistanceOracle {
        DistanceOracle { machine: machine.clone(), backend: Backend::Implicit }
    }

    /// Materialized matrix. Caller asserts `k` is small enough; prefer
    /// [`DistanceOracle::auto`].
    pub fn dense(machine: &Machine) -> DistanceOracle {
        DistanceOracle { machine: machine.clone(), backend: Backend::Dense(machine.dense_matrix()) }
    }

    /// Blocked row cache holding at most `slab_cap` slabs of
    /// [`SLAB_ROWS`] rows.
    pub fn blocked(machine: &Machine, slab_cap: usize) -> DistanceOracle {
        DistanceOracle {
            machine: machine.clone(),
            backend: Backend::Blocked(RowCache::new(slab_cap.max(1))),
        }
    }

    /// General-purpose pick: implicit for models whose lookups already
    /// are O(1) table reads, dense up to [`DENSE_K_MAX`], blocked row
    /// cache beyond (serial row-scanning loops like the QAP polish).
    pub fn auto(machine: &Machine) -> DistanceOracle {
        if machine.lookup_is_table() {
            Self::implicit(machine)
        } else if machine.k() <= DENSE_K_MAX {
            Self::dense(machine)
        } else {
            Self::blocked(machine, DEFAULT_SLAB_CAP)
        }
    }

    /// Refinement-flavor pick: implicit for table-backed models, dense
    /// up to [`DENSE_K_MAX`], implicit beyond — parallel gain kernels
    /// must not serialize on a cache lock, never materialize O(k²), and
    /// never duplicate a table the model already holds.
    pub fn for_refine(machine: &Machine) -> DistanceOracle {
        if machine.lookup_is_table() || machine.k() > DENSE_K_MAX {
            Self::implicit(machine)
        } else {
            Self::dense(machine)
        }
    }

    pub fn k(&self) -> usize {
        self.machine.k()
    }

    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    /// Backend name, for tests and diagnostics.
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Implicit => "implicit",
            Backend::Dense(_) => "dense",
            Backend::Blocked(_) => "blocked",
        }
    }

    /// One pairwise distance.
    #[inline]
    pub fn get(&self, x: Block, y: Block) -> f64 {
        match &self.backend {
            Backend::Implicit => self.machine.distance(x, y),
            Backend::Dense(m) => m.get(x, y),
            Backend::Blocked(c) => {
                let (slab, off) = c.slab_for(&self.machine, x);
                slab[off + y as usize]
            }
        }
    }

    /// The row `D[x, ·]` in whatever form the backend holds it — the unit
    /// the QAP loops and the gain tables consume.
    #[inline]
    pub fn row(&self, x: Block) -> OracleRow<'_> {
        match &self.backend {
            Backend::Implicit => OracleRow::Virtual { machine: &self.machine, x },
            Backend::Dense(m) => OracleRow::Slice(m.row(x)),
            Backend::Blocked(c) => {
                let (slab, off) = c.slab_for(&self.machine, x);
                OracleRow::Slab { slab, off }
            }
        }
    }

    /// Both rows as plain slices when the backend is dense — the gain
    /// kernels' fast path.
    #[inline]
    pub fn dense_rows(&self, x: Block, y: Block) -> Option<(&[f64], &[f64])> {
        match &self.backend {
            Backend::Dense(m) => Some((m.row(x), m.row(y))),
            _ => None,
        }
    }

    /// Mapping gain of moving a vertex with block connectivities `conn`
    /// from `from` to `to` (paper Eq. 1): `Σ_b conn(b)·(D[from,b] − D[to,b])`.
    pub fn gain(&self, conn: &[(Block, f64)], from: Block, to: Block) -> f64 {
        if let Some((rf, rt)) = self.dense_rows(from, to) {
            return conn.iter().map(|&(b, w)| w * (rf[b as usize] - rt[b as usize])).sum();
        }
        let rf = self.row(from);
        let rt = self.row(to);
        conn.iter().map(|&(b, w)| w * (rf.get(b) - rt.get(b))).sum()
    }
}

/// A borrowed view of one oracle row; `get(y)` answers `D[x, y]`.
pub enum OracleRow<'a> {
    /// Dense backend: a real slice.
    Slice(&'a [f64]),
    /// Blocked backend: a shared slab with this row at `off`.
    Slab { slab: Arc<Vec<f64>>, off: usize },
    /// Implicit backend: computed per element.
    Virtual { machine: &'a Machine, x: Block },
}

impl OracleRow<'_> {
    #[inline]
    pub fn get(&self, y: Block) -> f64 {
        match self {
            OracleRow::Slice(s) => s[y as usize],
            OracleRow::Slab { slab, off } => slab[off + y as usize],
            OracleRow::Virtual { machine, x } => machine.distance(*x, y),
        }
    }
}

/// Bounded FIFO of row slabs behind a mutex (correct under parallel use;
/// intended for serial hot loops).
#[derive(Debug)]
struct RowCache {
    slab_cap: usize,
    state: Mutex<CacheState>,
}

#[derive(Debug, Default)]
struct CacheState {
    slabs: HashMap<usize, Arc<Vec<f64>>>,
    order: VecDeque<usize>,
}

impl RowCache {
    fn new(slab_cap: usize) -> RowCache {
        RowCache { slab_cap, state: Mutex::new(CacheState::default()) }
    }

    /// The slab holding row `x`, plus the row's offset inside it.
    fn slab_for(&self, machine: &Machine, x: Block) -> (Arc<Vec<f64>>, usize) {
        let k = machine.k();
        let slab_id = x as usize / SLAB_ROWS;
        let off = (x as usize % SLAB_ROWS) * k;
        let mut st = self.state.lock().unwrap();
        if let Some(s) = st.slabs.get(&slab_id) {
            return (s.clone(), off);
        }
        let first = slab_id * SLAB_ROWS;
        let rows = SLAB_ROWS.min(k - first);
        let mut v = vec![0.0f64; rows * k];
        for r in 0..rows {
            let row_pe = (first + r) as Block;
            for y in 0..k {
                v[r * k + y] = machine.distance(row_pe, y as Block);
            }
        }
        let s = Arc::new(v);
        st.slabs.insert(slab_id, s.clone());
        st.order.push_back(slab_id);
        while st.order.len() > self.slab_cap {
            if let Some(old) = st.order.pop_front() {
                st.slabs.remove(&old);
            }
        }
        (s, off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> Machine {
        Machine::hier("4:8:2", "1:10:100").unwrap()
    }

    #[test]
    fn backends_agree_on_all_pairs() {
        let m = machine();
        let k = m.k();
        let implicit = DistanceOracle::implicit(&m);
        let dense = DistanceOracle::dense(&m);
        let blocked = DistanceOracle::blocked(&m, 2); // tiny cap → evictions
        for x in 0..k as Block {
            for y in 0..k as Block {
                let d = m.distance(x, y);
                assert_eq!(implicit.get(x, y), d, "implicit ({x},{y})");
                assert_eq!(dense.get(x, y), d, "dense ({x},{y})");
                assert_eq!(blocked.get(x, y), d, "blocked ({x},{y})");
            }
        }
    }

    #[test]
    fn rows_match_gets() {
        let m = machine();
        for oracle in
            [DistanceOracle::implicit(&m), DistanceOracle::dense(&m), DistanceOracle::blocked(&m, 4)]
        {
            for x in [0u32, 5, 31, 63] {
                let row = oracle.row(x);
                for y in 0..m.k() as Block {
                    assert_eq!(row.get(y), m.distance(x, y), "{} ({x},{y})", oracle.backend_name());
                }
            }
        }
    }

    #[test]
    fn auto_and_refine_pick_by_size_and_backing() {
        let small = machine();
        assert_eq!(DistanceOracle::auto(&small).backend_name(), "dense");
        assert_eq!(DistanceOracle::for_refine(&small).backend_name(), "dense");
        // 8192 PEs > DENSE_K_MAX.
        let big = Machine::parse_spec("torus:32x16x16").unwrap();
        assert_eq!(big.k(), 8192);
        assert_eq!(DistanceOracle::auto(&big).backend_name(), "blocked");
        assert_eq!(DistanceOracle::for_refine(&big).backend_name(), "implicit");
        // Table-backed models stay implicit: dense/blocked would only
        // duplicate the table the model already holds.
        let table = crate::topology::MatrixModel::from_text("2\n0 1\n1 0", "t").unwrap();
        let table = Machine::from_model(table).unwrap();
        assert_eq!(DistanceOracle::auto(&table).backend_name(), "implicit");
        assert_eq!(DistanceOracle::for_refine(&table).backend_name(), "implicit");
    }

    #[test]
    fn blocked_cache_stays_bounded_and_correct_past_eviction() {
        let m = Machine::parse_spec("torus:16x16").unwrap(); // k = 256 → 32 slabs
        let oracle = DistanceOracle::blocked(&m, 2);
        // Sweep every row twice: the second sweep re-fetches evicted slabs.
        for _ in 0..2 {
            for x in 0..m.k() as Block {
                assert_eq!(oracle.row(x).get(x), 0.0);
                assert_eq!(oracle.get(x, (x + 1) % m.k() as Block), m.distance(x, (x + 1) % 256));
            }
        }
    }

    #[test]
    fn gain_matches_manual_sum() {
        let m = machine();
        let conn = [(0u32, 2.0), (33u32, 1.5)];
        for oracle in [DistanceOracle::dense(&m), DistanceOracle::implicit(&m)] {
            let g = oracle.gain(&conn, 3, 40);
            let want: f64 = conn
                .iter()
                .map(|&(b, w)| w * (m.distance(3, b) - m.distance(40, b)))
                .sum();
            assert!((g - want).abs() < 1e-12, "{}", oracle.backend_name());
        }
    }
}
