//! `heipa` — CLI for the HeiPa-RS process-mapping framework.
//!
//! Subcommands:
//!
//! * `gen`     — generate benchmark instances (Table 1 suite) to METIS files
//! * `map`     — map one instance onto a hierarchy with any algorithm
//! * `eval`    — evaluate J(C, D, Π) of an existing partition file
//! * `phases`  — GPU-IM phase breakdown for one instance (Table 2 row)
//! * `suite`   — run an experiment matrix and write CSV
//! * `serve`   — start the mapping-as-a-service coordinator (TCP)
//!
//! Flags are `--key value`; run `heipa help` for details. (The offline
//! crate set has no clap; parsing is hand-rolled in [`args`].)

use anyhow::{bail, Context, Result};
use heipa::algo::{run_algorithm, Algorithm};
use heipa::coordinator::service::Service;
use heipa::graph::{gen, io};
use heipa::harness;
use heipa::metrics::Phase;
use heipa::par::Pool;
use heipa::topology::Hierarchy;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal `--key value` argument parser.
struct Args {
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = BTreeMap::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument `{a}`");
            };
            let val = it.next().with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn required(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing required flag --{key}"))
    }
}

fn load_graph(name_or_path: &str) -> Result<heipa::graph::CsrGraph> {
    if gen::instance_by_name(name_or_path).is_some() {
        Ok(gen::generate_by_name(name_or_path))
    } else {
        io::read_metis(Path::new(name_or_path))
    }
}

fn hierarchy_of(args: &Args) -> Result<Hierarchy> {
    Hierarchy::parse(&args.get_or("hier", "4:8:6"), &args.get_or("dist", "1:10:100"))
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd {
        "help" | "--help" | "-h" => print_help(),
        "gen" => cmd_gen(&args)?,
        "map" => cmd_map(&args)?,
        "eval" => cmd_eval(&args)?,
        "phases" => cmd_phases(&args)?,
        "suite" => cmd_suite(&args)?,
        "serve" => cmd_serve(&args)?,
        other => bail!("unknown subcommand `{other}` (try `heipa help`)"),
    }
    Ok(())
}

fn print_help() {
    println!(
        "heipa — GPU-accelerated process mapping (paper reproduction)\n\
         \n\
         USAGE: heipa <subcommand> [--key value …]\n\
         \n\
         gen    --suite paper|smoke [--out-dir DIR] [--stats 1]\n\
         map    --graph NAME|FILE [--algo gpu-im] [--hier 4:8:6] [--dist 1:10:100]\n\
                [--eps 0.03] [--seed 1] [--out part.txt]\n\
         eval   --graph NAME|FILE --part FILE [--hier …] [--dist …]\n\
         phases --graph NAME|FILE [--hier …] [--dist …] [--seed 1]\n\
         suite  --algos a,b,… [--instances x,y|smoke|paper] [--seeds 1,2]\n\
                [--out results.csv] [--eps 0.03]\n\
         serve  [--addr 127.0.0.1:7171] [--artifacts artifacts] [--threads 0]\n\
         \n\
         Algorithms: {}",
        Algorithm::all().map(|a| a.name()).join(", ")
    );
}

fn cmd_gen(args: &Args) -> Result<()> {
    let suite = match args.get_or("suite", "paper").as_str() {
        "paper" => gen::paper_suite(),
        "smoke" => gen::smoke_suite(),
        other => bail!("unknown suite `{other}`"),
    };
    let out_dir = args.get("out-dir").map(PathBuf::from);
    let stats = args.get("stats").is_some();
    if let Some(dir) = &out_dir {
        std::fs::create_dir_all(dir)?;
    }
    println!("| instance | group | stand-in for | n | m | class |");
    println!("|---|---|---|---|---|---|");
    for spec in suite {
        let g = spec.generate();
        if stats || out_dir.is_some() {
            println!(
                "| {} | {} | {} | {} | {} | {:?} |",
                spec.name,
                spec.group,
                spec.stand_in_for,
                g.n(),
                g.m(),
                spec.size_class()
            );
        }
        if let Some(dir) = &out_dir {
            io::write_metis(&g, &dir.join(format!("{}.graph", spec.name)))?;
        }
    }
    Ok(())
}

fn cmd_map(args: &Args) -> Result<()> {
    let g = load_graph(args.required("graph")?)?;
    let h = hierarchy_of(args)?;
    let algo = Algorithm::from_name(&args.get_or("algo", "gpu-im"))
        .context("unknown --algo (try `heipa help`)")?;
    let eps: f64 = args.get_or("eps", "0.03").parse()?;
    let seed: u64 = args.get_or("seed", "1").parse()?;
    let pool = Pool::default();
    let r = run_algorithm(algo, &pool, &g, &h, eps, seed);
    println!(
        "instance={} n={} m={} k={} algo={} J={:.3} imbalance={:.5} host_ms={:.2} device_ms={:.3}",
        args.required("graph")?,
        g.n(),
        g.m(),
        h.k(),
        algo.name(),
        r.comm_cost,
        r.imbalance,
        r.host_ms,
        r.device_ms,
    );
    if let Some(out) = args.get("out") {
        io::write_partition(&r.mapping, Path::new(out))?;
        println!("wrote mapping to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let g = load_graph(args.required("graph")?)?;
    let part = io::read_partition(Path::new(args.required("part")?))?;
    let h = hierarchy_of(args)?;
    heipa::partition::validate_mapping(&part, g.n(), h.k()).map_err(anyhow::Error::msg)?;
    println!(
        "J={:.3} edge_cut={:.3} imbalance={:.5}",
        heipa::partition::comm_cost(&g, &part, &h),
        heipa::partition::edge_cut(&g, &part),
        heipa::partition::imbalance(&g, &part, h.k()),
    );
    Ok(())
}

fn cmd_phases(args: &Args) -> Result<()> {
    let g = load_graph(args.required("graph")?)?;
    let h = hierarchy_of(args)?;
    let seed: u64 = args.get_or("seed", "1").parse()?;
    let pool = Pool::default();
    let r = run_algorithm(Algorithm::GpuIm, &pool, &g, &h, 0.03, seed);
    let phases = r.phases.expect("gpu-im reports phases");
    println!("GPU-IM phase breakdown — n={} m={} k={} (modeled device time)", g.n(), g.m(), h.k());
    println!("| phase | share | ms |");
    println!("|---|---|---|");
    for (label, share, ms) in phases.rows() {
        println!("| {label} | {share:.2}% | {ms:.3} |");
    }
    println!("| Total | 100% | {:.3} |", phases.total_device_ms());
    let _ = Phase::all();
    Ok(())
}

fn cmd_suite(args: &Args) -> Result<()> {
    let algos: Vec<Algorithm> = args
        .get_or("algos", "gpu-hm-ultra,gpu-im,sharedmap-f,intmap-f")
        .split(',')
        .map(|s| Algorithm::from_name(s.trim()).with_context(|| format!("unknown algorithm {s}")))
        .collect::<Result<_>>()?;
    let instances = match args.get_or("instances", "smoke").as_str() {
        "paper" => gen::paper_suite(),
        "smoke" => gen::smoke_suite(),
        list => {
            list.split(',')
                .map(|name| {
                    gen::instance_by_name(name.trim())
                        .with_context(|| format!("unknown instance {name}"))
                })
                .collect::<Result<Vec<_>>>()?
        }
    };
    let seeds: Vec<u64> = args
        .get_or("seeds", "1")
        .split(',')
        .map(|s| s.trim().parse::<u64>().map_err(Into::into))
        .collect::<Result<_>>()?;
    let eps: f64 = args.get_or("eps", "0.03").parse()?;
    let hierarchies = harness::hierarchies_from_env();
    let pool = Pool::default();
    let records = harness::run_matrix(&algos, &instances, &hierarchies, &seeds, eps, &pool);
    let out = args.get_or("out", "results.csv");
    harness::write_csv(&records, Path::new(&out))?;
    println!("wrote {} records to {out}", records.len());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7171");
    let artifacts = args.get_or("artifacts", "artifacts");
    let threads: usize = args.get_or("threads", "0").parse()?;
    let svc = std::sync::Arc::new(Service::start(artifacts, threads));
    heipa::coordinator::protocol::serve_tcp(svc, &addr)
}
