//! `heipa` — CLI for the HeiPa-RS process-mapping framework.
//!
//! Subcommands:
//!
//! * `gen`     — generate benchmark instances (Table 1 suite) to METIS files
//! * `map`     — map one instance onto a hierarchy with any solver
//! * `eval`    — evaluate J(C, D, Π) of an existing partition file
//! * `phases`  — GPU-IM phase breakdown for one instance (Table 2 row)
//! * `suite`   — run an experiment matrix and write CSV
//! * `serve`   — start the mapping-as-a-service coordinator (TCP job API)
//! * `cluster` — spawn/supervise a local fleet: router + N `serve` engine nodes
//! * `client`  — drive a running coordinator over the async wire protocol
//!
//! Every mapping subcommand builds an [`heipa::engine::MapSpec`] — from a
//! `--config FILE` (`key = value`, see [`heipa::config::RunConfig`]) when
//! given, with CLI flags overriding file keys — and hands it to one
//! [`heipa::engine::Engine`]. Flags are `--key value`; boolean flags
//! (`--polish`, `--stats`) may omit the value. Run `heipa help` for
//! details. (The offline crate set has no clap; parsing is hand-rolled in
//! [`Args`].)

use anyhow::{bail, Context, Result};
use heipa::algo::Algorithm;
use heipa::config::RunConfig;
use heipa::coordinator::service::{Service, ServiceConfig};
use heipa::engine::{solver_names, Engine, EngineConfig, MapOutcome, MapSpec, Refinement};
use heipa::graph::{gen, io};
use heipa::harness;
use heipa::topology::Machine;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Flags that may appear without a value (`--polish` ≡ `--polish 1`).
const BOOL_FLAGS: &[&str] = &["stats", "polish"];

/// Minimal `--key value` argument parser with valueless boolean flags.
struct Args {
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = BTreeMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument `{a}`");
            };
            let val = if BOOL_FLAGS.contains(&key) {
                // Consume an explicit 0/1/true/false if present, otherwise
                // the bare flag means true — never swallow the next flag.
                match it.peek().map(|s| s.as_str()) {
                    Some("0") | Some("1") | Some("true") | Some("false") => it.next().unwrap().clone(),
                    _ => "1".to_string(),
                }
            } else {
                it.next().with_context(|| format!("--{key} needs a value"))?.clone()
            };
            flags.insert(key.to_string(), val);
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("1") | Some("true"))
    }

    fn required(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing required flag --{key}"))
    }
}

/// The machine model named by the flags: `--topology SPEC` wins, the
/// `--hier`/`--dist` pair otherwise.
fn machine_of(args: &Args) -> Result<Machine> {
    Machine::resolve(
        args.get("topology"),
        &args.get_or("hier", "4:8:6"),
        &args.get_or("dist", "1:10:100"),
    )
}

/// The layered spec construction every mapping subcommand shares:
/// `RunConfig` defaults → `--config FILE` keys → CLI flag overrides.
/// Returns the spec plus the engine parameters the config carries.
fn spec_from_args(args: &Args) -> Result<(MapSpec, EngineConfig)> {
    let from_file = args.get("config").is_some();
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    if !from_file {
        // The multi-seed default is a config-file/paper convention; the
        // bare CLI maps one seed like it always did.
        cfg.seeds = vec![1];
    }
    let graph = args
        .get("graph")
        .map(str::to_string)
        .or_else(|| cfg.graph.clone())
        .context("missing --graph (flag or `graph =` config key)")?;
    let mut spec = cfg.to_spec(&graph);
    if let Some(v) = args.get("hier") {
        spec.hierarchy = v.to_string();
    }
    if let Some(v) = args.get("dist") {
        spec.distance = v.to_string();
    }
    if let Some(v) = args.get("topology") {
        spec.topology = Some(v.to_string());
    } else if args.get("hier").is_some() || args.get("dist").is_some() {
        // Explicit flags always win: an explicit --hier/--dist must not
        // be silently shadowed by a `topology =` key from the config.
        spec.topology = None;
    }
    if let Some(v) = args.get("eps") {
        spec.eps = v.parse().context("--eps")?;
    }
    if let Some(v) = args.get("seed") {
        spec.seeds = parse_seeds(v)?;
    }
    if let Some(v) = args.get("algo") {
        spec.algorithm = parse_algo(v)?;
    }
    if let Some(v) = args.get("refine") {
        spec.refinement = Refinement::from_name(v)?;
    }
    if let Some(v) = args.get("coarsening") {
        spec.coarsening = heipa::multilevel::SchemeKind::from_name(v)?;
    }
    if args.get("polish").is_some() {
        spec.polish = args.get_bool("polish");
    }
    if let Some(v) = args.get("backend") {
        spec.backend = heipa::engine::Backend::from_name(v)?;
    }
    if let Some(list) = args.get("opts") {
        for kv in list.split(',').filter(|s| !s.trim().is_empty()) {
            let (k, v) = kv.split_once('=').with_context(|| format!("--opts entry `{kv}` (want k=v)"))?;
            spec.options.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    let mut ecfg = cfg.engine_config();
    if let Some(v) = args.get("threads") {
        ecfg.threads = v.parse().context("--threads")?;
    }
    if let Some(v) = args.get("artifacts") {
        ecfg.artifacts_dir = v.to_string();
    }
    Ok((spec, ecfg))
}

fn parse_seeds(v: &str) -> Result<Vec<u64>> {
    let seeds: Vec<u64> = v
        .split(',')
        .map(|s| s.trim().parse::<u64>().map_err(Into::into))
        .collect::<Result<_>>()?;
    if seeds.is_empty() {
        bail!("--seed needs at least one seed");
    }
    Ok(seeds)
}

/// `auto` unpins; anything else must be a registry solver name.
fn parse_algo(v: &str) -> Result<Option<Algorithm>> {
    if v == "auto" {
        return Ok(None);
    }
    heipa::engine::solver_by_name(v)
        .map(|s| Some(s.algorithm()))
        .with_context(|| format!("unknown --algo `{v}` (try `heipa help`)"))
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd {
        "help" | "--help" | "-h" => print_help(),
        "gen" => cmd_gen(&args)?,
        "map" => cmd_map(&args)?,
        "eval" => cmd_eval(&args)?,
        "phases" => cmd_phases(&args)?,
        "suite" => cmd_suite(&args)?,
        "serve" => cmd_serve(&args)?,
        "cluster" => cmd_cluster(&args)?,
        "client" => cmd_client(&args)?,
        other => bail!("unknown subcommand `{other}` (try `heipa help`)"),
    }
    Ok(())
}

fn print_help() {
    println!(
        "heipa — GPU-accelerated process mapping (paper reproduction)\n\
         \n\
         USAGE: heipa <subcommand> [--key value …]\n\
         \n\
         gen    --suite paper|smoke [--out-dir DIR] [--stats]\n\
         map    --graph NAME|FILE [--config FILE] [--algo gpu-im|auto] [--hier 4:8:6]\n\
                [--dist 1:10:100] [--topology SPEC] [--eps 0.03] [--seed 1,2,…]\n\
                [--refine standard|strong] [--coarsening matching|cluster|auto]\n\
                [--polish] [--backend cpu|device|auto] [--opts k=v,…]\n\
                [--artifacts DIR] [--threads N] [--out part.txt]\n\
         eval   --graph NAME|FILE --part FILE [--hier …] [--dist …] [--topology SPEC]\n\
         phases --graph NAME|FILE [--hier …] [--dist …] [--topology SPEC] [--seed 1]\n\
         suite  --algos a,b,… [--config FILE] [--instances x,y|smoke|paper] [--seeds 1,2]\n\
                [--out results.csv] [--eps 0.03]\n\
         serve  [--addr 127.0.0.1:7171] [--artifacts artifacts] [--threads 0] [--cache-cap 64]\n\
                [--workers 2] [--queue-cap 256] [--max-conns 64] [--max-attempts 1]\n\
                [--backoff-ms 100] [--read-timeout-ms 120000] [--max-line-len 4194304]\n\
         cluster [--addr 127.0.0.1:7070] [--nodes 2 | --join ADDR,ADDR,…] [--replication 2]\n\
                [--probe-ms 500] [--request-timeout-ms 120000] [--max-conns 64]\n\
                (plus --workers/--queue-cap/--max-attempts/--backoff-ms/--artifacts/\n\
                --threads/--cache-cap, passed through to each spawned engine node)\n\
         client --addr HOST:PORT (--send \"CMD\" | --script \"CMD; CMD; …\" | --batch FILE)\n\
                [--timeout-ms 60000]\n\
         \n\
         The serve wire protocol is an async job API: `submit …` returns `ok job=<id>`\n\
         immediately; poll with `status`/`wait`/`result`/`cancel`/`jobs`; upload task\n\
         graphs once with `graph put name=… path=…|csr=…` and map them by `graph=<name>`\n\
         (full grammar in README \"Service & job API\"). `graph patch name=… ops=…` edits\n\
         a pinned graph in place; the next map over it warm-starts from the previous\n\
         mapping (`remap=warm`, README \"Incremental remapping & batching\").\n\
         `client --batch FILE` submits one job per line of FILE (submit body syntax,\n\
         `#` comments) as a single all-or-nothing batch and waits for it to retire.\n\
         --max-attempts/--backoff-ms set\n\
         the default retry policy (per-job `max_attempts=`/`backoff_ms=` keys override);\n\
         exhausted retries degrade through the solver fallback chain instead of failing\n\
         (README \"Fault tolerance & degradation\").\n\
         \n\
         --coarsening picks the multilevel coarsening scheme (matching, size-\n\
         constrained cluster LP, or auto = matching with per-level cluster fallback).\n\
         --backend runs the hot multilevel kernels on the cpu worker pool (default),\n\
         on the PJRT device runtime (`device`, needs `make artifacts`; falls back to\n\
         cpu when artifacts are missing), or probes per job (`auto`). The wire key is\n\
         `backend=` on submit/map lines (README \"Device offload\").\n\
         `--config FILE` reads `key = value` defaults (see config::RunConfig);\n\
         explicit flags always win. Boolean flags (--polish, --stats) take no value.\n\
         --topology SPEC picks a machine model and overrides --hier/--dist:\n\
         hier:4:8:6/1:10:100, torus:4x4x4, mesh:16x16, fattree:3:2,16,48/1,5,20,\n\
         dragonfly:8:4:4/1,2,5, hetero:4+8+4/1,10, file:PATH (see README).\n\
         \n\
         Solvers: {}",
        solver_names().join(", ")
    );
}

fn cmd_gen(args: &Args) -> Result<()> {
    let suite = match args.get_or("suite", "paper").as_str() {
        "paper" => gen::paper_suite(),
        "smoke" => gen::smoke_suite(),
        other => bail!("unknown suite `{other}`"),
    };
    let out_dir = args.get("out-dir").map(PathBuf::from);
    let stats = args.get_bool("stats");
    if let Some(dir) = &out_dir {
        std::fs::create_dir_all(dir)?;
    }
    println!("| instance | group | stand-in for | n | m | class |");
    println!("|---|---|---|---|---|---|");
    for spec in suite {
        let g = spec.generate();
        if stats || out_dir.is_some() {
            println!(
                "| {} | {} | {} | {} | {} | {:?} |",
                spec.name,
                spec.group,
                spec.stand_in_for,
                g.n(),
                g.m(),
                spec.size_class()
            );
        }
        if let Some(dir) = &out_dir {
            io::write_metis(&g, &dir.join(format!("{}.graph", spec.name)))?;
        }
    }
    Ok(())
}

fn print_outcome(graph: &str, r: &MapOutcome) {
    let mut line = format!(
        "instance={} n={} k={} algo={} seed={} J={:.3} imbalance={:.5} host_ms={:.2} device_ms={:.3} polish_dj={:.3}",
        graph,
        r.n,
        r.k,
        r.algorithm.name(),
        r.seed,
        r.comm_cost,
        r.imbalance,
        r.host_ms,
        r.device_ms,
        r.polish_improvement,
    );
    if r.backend == heipa::engine::Backend::Device {
        line.push_str(" backend=device");
    }
    println!("{line}");
}

fn cmd_map(args: &Args) -> Result<()> {
    let (spec, ecfg) = spec_from_args(args)?;
    let graph_label = match &spec.graph {
        heipa::engine::GraphSource::Named(n) => n.clone(),
        heipa::engine::GraphSource::InMemory(_) => "<in-memory>".into(),
    };
    let engine = Engine::new(ecfg);
    let outcomes = engine.map_all_seeds(&spec)?;
    for r in &outcomes {
        print_outcome(&graph_label, r);
    }
    let best = outcomes
        .iter()
        .min_by(|a, b| a.comm_cost.total_cmp(&b.comm_cost))
        .context("no seeds ran")?;
    if outcomes.len() > 1 {
        println!("best: seed={} J={:.3}", best.seed, best.comm_cost);
    }
    if let Some(out) = args.get("out") {
        io::write_partition(&best.mapping, Path::new(out))?;
        println!("wrote mapping to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let engine = Engine::with_defaults();
    let g = engine.resolve_graph(&heipa::engine::GraphSource::Named(args.required("graph")?.to_string()))?;
    let part = io::read_partition(Path::new(args.required("part")?))?;
    let m = machine_of(args)?;
    heipa::partition::validate_mapping(&part, g.n(), m.k()).map_err(anyhow::Error::msg)?;
    let q = heipa::metrics::mapping_quality(&g, &part, &m);
    println!(
        "J={:.3} edge_cut={:.3} imbalance={:.5} machine={}",
        q.comm_cost,
        q.edge_cut,
        q.imbalance,
        m.label(),
    );
    Ok(())
}

fn cmd_phases(args: &Args) -> Result<()> {
    let graph = args.required("graph")?.to_string();
    let mut spec = MapSpec::named(graph)
        .hierarchy(args.get_or("hier", "4:8:6"))
        .distance(args.get_or("dist", "1:10:100"))
        .seed(args.get_or("seed", "1").parse()?)
        .algo(Some(Algorithm::GpuIm));
    if let Some(v) = args.get("topology") {
        spec.topology = Some(v.to_string());
    }
    if let Some(v) = args.get("coarsening") {
        spec.coarsening = heipa::multilevel::SchemeKind::from_name(v)?;
    }
    let engine = Engine::with_defaults();
    let r = engine.map(&spec)?;
    let phases = r.phases.expect("gpu-im reports phases");
    println!("GPU-IM phase breakdown — n={} k={} (modeled device time)", r.n, r.k);
    println!("| phase | share | ms |");
    println!("|---|---|---|");
    for (label, share, ms) in phases.rows() {
        println!("| {label} | {share:.2}% | {ms:.3} |");
    }
    println!("| Total | 100% | {:.3} |", phases.total_device_ms());
    Ok(())
}

fn cmd_suite(args: &Args) -> Result<()> {
    let cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    let algos: Vec<Algorithm> = args
        .get_or("algos", "gpu-hm-ultra,gpu-im,sharedmap-f,intmap-f")
        .split(',')
        .map(|s| {
            heipa::engine::solver_by_name(s.trim())
                .map(|sv| sv.algorithm())
                .with_context(|| format!("unknown algorithm {s}"))
        })
        .collect::<Result<_>>()?;
    let instances = match args.get_or("instances", "smoke").as_str() {
        "paper" => gen::paper_suite(),
        "smoke" => gen::smoke_suite(),
        list => {
            list.split(',')
                .map(|name| {
                    gen::instance_by_name(name.trim())
                        .with_context(|| format!("unknown instance {name}"))
                })
                .collect::<Result<Vec<_>>>()?
        }
    };
    let seeds: Vec<u64> = match args.get("seeds") {
        Some(v) => parse_seeds(v)?,
        None if args.get("config").is_some() => cfg.seeds.clone(),
        None => vec![1],
    };
    let eps: f64 = match args.get("eps") {
        Some(v) => v.parse().context("--eps")?,
        None => cfg.eps,
    };
    // Machines: a config file pins one model; HEIPA_TOPS (or no config)
    // sweeps the paper family and/or explicit topology specs.
    let machines = if args.get("config").is_some() && std::env::var("HEIPA_TOPS").is_err() {
        vec![cfg.machine()?]
    } else {
        harness::machines_from_env()
    };
    // The matrix pins algorithms and never polishes; refuse to silently
    // drop config keys the suite cannot honor.
    if cfg.polish || cfg.refinement != Refinement::Standard || !cfg.options.is_empty() {
        eprintln!(
            "warning: `suite` ignores the config keys polish/refinement/opt.* (the matrix pins solver flavors explicitly)"
        );
    }
    let mut ecfg = cfg.engine_config();
    if let Some(v) = args.get("threads") {
        ecfg.threads = v.parse().context("--threads")?;
    }
    let engine = Engine::new(ecfg);
    let records = harness::run_matrix(&engine, &algos, &instances, &machines, &seeds, eps);
    let out = args.get_or("out", "results.csv");
    harness::write_csv(&records, Path::new(&out))?;
    println!("wrote {} records to {out}", records.len());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7171");
    let svc = std::sync::Arc::new(Service::with_config(ServiceConfig {
        artifacts_dir: args.get_or("artifacts", "artifacts"),
        threads: args.get_or("threads", "0").parse()?,
        graph_cache_cap: args.get_or("cache-cap", "64").parse().context("--cache-cap")?,
        workers: args.get_or("workers", "2").parse().context("--workers")?,
        queue_cap: args.get_or("queue-cap", "256").parse().context("--queue-cap")?,
        retry: heipa::engine::RetryPolicy {
            max_attempts: args
                .get_or("max-attempts", "1")
                .parse::<u32>()
                .context("--max-attempts")?
                .max(1),
            base_backoff: std::time::Duration::from_millis(
                args.get_or("backoff-ms", "100").parse().context("--backoff-ms")?,
            ),
        },
        ..ServiceConfig::default()
    }));
    let defaults = heipa::coordinator::protocol::ServeOptions::default();
    let opts = heipa::coordinator::protocol::ServeOptions {
        max_conns: args.get_or("max-conns", "64").parse().context("--max-conns")?,
        read_timeout_ms: args
            .get_or("read-timeout-ms", &defaults.read_timeout_ms.to_string())
            .parse()
            .context("--read-timeout-ms")?,
        max_line_len: args
            .get_or("max-line-len", &defaults.max_line_len.to_string())
            .parse()
            .context("--max-line-len")?,
    };
    heipa::coordinator::protocol::serve_tcp(svc, &addr, opts)
}

/// Spawn and supervise a local fleet: N `heipa serve` engine children on
/// ephemeral ports (or `--join` an existing set of addresses), then run
/// the cluster router in front of them. Each child's address and pid are
/// printed (`node I: addr=A pid=P`) before the router binds, so scripts
/// can target — or kill — individual engines. Child stdout is drained
/// under a `node I|` prefix so a chatty engine can never block on a full
/// pipe; a child exiting is reported but not restarted (the router's
/// failover re-homes its work onto the survivors).
fn cmd_cluster(args: &Args) -> Result<()> {
    use std::io::{BufRead, BufReader};
    use std::process::{Child, Command, Stdio};
    let addr = args.get_or("addr", "127.0.0.1:7070");
    let replication: usize = args.get_or("replication", "2").parse().context("--replication")?;
    let probe_ms: u64 = args.get_or("probe-ms", "500").parse().context("--probe-ms")?;
    let mut node_addrs: Vec<String> = Vec::new();
    let mut children: Vec<Child> = Vec::new();
    if let Some(list) = args.get("join") {
        node_addrs =
            list.split(',').map(str::trim).filter(|s| !s.is_empty()).map(str::to_string).collect();
        if node_addrs.is_empty() {
            bail!("--join needs at least one HOST:PORT");
        }
    } else {
        let n: usize = args.get_or("nodes", "2").parse().context("--nodes")?;
        if n == 0 {
            bail!("--nodes must be at least 1");
        }
        let exe = std::env::current_exe().context("locate the heipa binary")?;
        for i in 0..n {
            let mut cmd = Command::new(&exe);
            cmd.arg("serve").arg("--addr").arg("127.0.0.1:0");
            for flag in [
                "workers", "queue-cap", "max-attempts", "backoff-ms", "artifacts", "threads",
                "cache-cap",
            ] {
                if let Some(v) = args.get(flag) {
                    cmd.arg(format!("--{flag}")).arg(v);
                }
            }
            cmd.stdin(Stdio::null()).stdout(Stdio::piped());
            let mut child = cmd.spawn().with_context(|| format!("spawn engine node {i}"))?;
            let stdout = child.stdout.take().expect("stdout was piped");
            let mut reader = BufReader::new(stdout);
            // The first line a node prints announces its bound address.
            let mut line = String::new();
            let node_addr = loop {
                line.clear();
                if reader.read_line(&mut line)? == 0 {
                    bail!("engine node {i} exited before binding a port");
                }
                if let Some((_, a)) = line.trim_end().rsplit_once("listening on ") {
                    break a.to_string();
                }
            };
            println!("node {i}: addr={node_addr} pid={}", child.id());
            std::thread::Builder::new()
                .name(format!("heipa-node-out-{i}"))
                .spawn(move || {
                    let mut line = String::new();
                    loop {
                        line.clear();
                        match reader.read_line(&mut line) {
                            Ok(0) | Err(_) => {
                                println!("node {i}: exited");
                                return;
                            }
                            Ok(_) => print!("node {i}| {line}"),
                        }
                    }
                })
                .context("spawn node output drain")?;
            node_addrs.push(node_addr);
            children.push(child);
        }
    }
    let cfg = heipa::cluster::RouterConfig {
        replication,
        request_timeout_ms: args
            .get_or("request-timeout-ms", "120000")
            .parse()
            .context("--request-timeout-ms")?,
        plane: None,
    };
    let router = std::sync::Arc::new(heipa::cluster::Router::new(&node_addrs, cfg));
    if probe_ms > 0 {
        router.start_probes(std::time::Duration::from_millis(probe_ms));
    }
    let defaults = heipa::coordinator::protocol::ServeOptions::default();
    let opts = heipa::coordinator::protocol::ServeOptions {
        max_conns: args.get_or("max-conns", "64").parse().context("--max-conns")?,
        read_timeout_ms: args
            .get_or("read-timeout-ms", &defaults.read_timeout_ms.to_string())
            .parse()
            .context("--read-timeout-ms")?,
        max_line_len: args
            .get_or("max-line-len", &defaults.max_line_len.to_string())
            .parse()
            .context("--max-line-len")?,
    };
    let result = heipa::cluster::serve_router(router, &addr, opts);
    for mut child in children {
        let _ = child.kill();
    }
    result
}

/// Drive a running coordinator: send protocol lines, print each reply.
/// `--send` sends one command; `--script` sends several, `;`-separated,
/// over one connection (so job ids from `submit` can be awaited by later
/// commands in the same script via a shell loop); `--batch FILE` turns
/// one submit body per line of FILE into a single `batch submit` (all-
/// or-nothing admission) and follows it with `batch wait`. Protocol-
/// level `err` replies are printed, not fatal — transport failures are.
fn cmd_client(args: &Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let addr = args.required("addr")?;
    let batch_mode = args.get("batch").is_some();
    let commands: Vec<String> = if let Some(path) = args.get("batch") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read batch file {path}"))?;
        let jobs: Vec<String> = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .map(heipa::coordinator::protocol::escape_value)
            .collect();
        if jobs.is_empty() {
            bail!("batch file {path} has no jobs (one `key=value …` submit body per line)");
        }
        vec![format!("batch submit jobs={}", jobs.join(";"))]
    } else if let Some(cmd) = args.get("send") {
        vec![cmd.to_string()]
    } else if let Some(script) = args.get("script") {
        script.split(';').map(str::trim).filter(|s| !s.is_empty()).map(str::to_string).collect()
    } else {
        bail!("client needs --send \"CMD\", --script \"CMD; CMD; …\" or --batch FILE");
    };
    let timeout_ms: u64 = args.get_or("timeout-ms", "60000").parse().context("--timeout-ms")?;
    let mut conn = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connect to coordinator at {addr}"))?;
    conn.set_read_timeout(Some(std::time::Duration::from_millis(timeout_ms.max(1))))?;
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut last_reply = String::new();
    for cmd in commands {
        writeln!(conn, "{cmd}")?;
        let mut reply = String::new();
        let n = reader.read_line(&mut reply).context("read reply (timeout?)")?;
        if n == 0 {
            bail!("server closed the connection");
        }
        print!("{reply}");
        last_reply = reply;
    }
    if batch_mode {
        // Block until the whole batch retires so shell pipelines can
        // treat `client --batch` as synchronous.
        let Some(id) = last_reply.split_whitespace().find_map(|t| t.strip_prefix("batch=")) else {
            bail!("batch submit was rejected: {}", last_reply.trim_end());
        };
        writeln!(conn, "batch wait id={id}")?;
        let mut reply = String::new();
        let n = reader.read_line(&mut reply).context("read reply (timeout?)")?;
        if n == 0 {
            bail!("server closed the connection");
        }
        print!("{reply}");
    }
    Ok(())
}
