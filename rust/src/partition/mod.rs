//! Partitions/mappings and their quality metrics: balance, edge-cut, and
//! the communication cost `J(C, D, Π) = Σ_{ij} C_ij · D_{Π(i)Π(j)}`.

use crate::graph::CsrGraph;
use crate::par::Pool;
use crate::topology::{DistanceOracle, Machine};
use crate::{Block, EWeight, VWeight, Vertex};

/// Maximum allowed block weight `L_max = ⌈(1+ε)·c(V)/k⌉`.
pub fn l_max(total_weight: VWeight, k: usize, eps: f64) -> VWeight {
    ((1.0 + eps) * total_weight as f64 / k as f64).ceil() as VWeight
}

/// Per-block vertex weights `c(V_i)`.
pub fn block_weights(g: &CsrGraph, part: &[Block], k: usize) -> Vec<VWeight> {
    let mut w = vec![0 as VWeight; k];
    for v in 0..g.n() {
        w[part[v] as usize] += g.vw[v];
    }
    w
}

/// Heaviest block weight.
pub fn max_block_weight(g: &CsrGraph, part: &[Block], k: usize) -> VWeight {
    block_weights(g, part, k).into_iter().max().unwrap_or(0)
}

/// Achieved imbalance: `max_i c(V_i) · k / c(V) − 1`.
pub fn imbalance(g: &CsrGraph, part: &[Block], k: usize) -> f64 {
    let total = g.total_vweight();
    if total == 0 {
        return 0.0;
    }
    max_block_weight(g, part, k) as f64 * k as f64 / total as f64 - 1.0
}

/// Is the partition ε-balanced?
pub fn is_balanced(g: &CsrGraph, part: &[Block], k: usize, eps: f64) -> bool {
    max_block_weight(g, part, k) <= l_max(g.total_vweight(), k, eps)
}

/// Edge-cut `Σ_{i<j} ω(E_ij)` (each undirected cut edge counted once).
pub fn edge_cut(g: &CsrGraph, part: &[Block]) -> EWeight {
    let mut cut = 0.0;
    for v in 0..g.n() {
        let (nbrs, ws) = g.neighbors_w(v as Vertex);
        for (&u, &w) in nbrs.iter().zip(ws) {
            if part[v] != part[u as usize] {
                cut += w;
            }
        }
    }
    cut / 2.0
}

/// Communication cost `J(C, D, Π)` under any machine model (distances
/// via the model's implicit oracle — nothing is materialized). The task
/// graph stores each communication pair as two directed slots; the
/// paper's `Σ_{ij}` runs over the full matrix, so summing directed slots
/// matches the definition.
pub fn comm_cost(g: &CsrGraph, part: &[Block], m: &Machine) -> f64 {
    let mut j = 0.0;
    for v in 0..g.n() {
        let (nbrs, ws) = g.neighbors_w(v as Vertex);
        let pv = part[v];
        for (&u, &w) in nbrs.iter().zip(ws) {
            j += w * m.distance(pv, part[u as usize]);
        }
    }
    j
}

/// Edge-parallel `J(C, D, Π)` over the extended CSR (device kernel shape).
pub fn comm_cost_par(pool: &Pool, g: &CsrGraph, eu: &[Vertex], part: &[Block], m: &Machine) -> f64 {
    pool.reduce_sum_f64(g.num_directed(), |i| {
        let u = eu[i] as usize;
        let v = g.adj[i] as usize;
        g.ew[i] * m.distance(part[u], part[v])
    })
}

/// Block communication matrix `B[x][y] = Σ_{cut edges between x,y} w`
/// (the "communication model graph" G_M of Kaffpa-Map; also the input to
/// the one-to-one QAP mapping phase).
pub fn block_comm_matrix(g: &CsrGraph, part: &[Block], k: usize) -> Vec<f64> {
    let mut b = vec![0.0; k * k];
    for v in 0..g.n() {
        let (nbrs, ws) = g.neighbors_w(v as Vertex);
        let pv = part[v] as usize;
        for (&u, &w) in nbrs.iter().zip(ws) {
            let pu = part[u as usize] as usize;
            if pu != pv {
                b[pv * k + pu] += w;
            }
        }
    }
    b
}

/// `J` evaluated from a block communication matrix and a PE assignment
/// `sigma : block → PE` (the two-phase decomposition: J = Σ B_xy · D_{σx σy}).
/// Consumes oracle rows — one `D[σx, ·]` fetch per outer block.
pub fn comm_cost_blocks(bmat: &[f64], k: usize, sigma: &[Block], d: &DistanceOracle) -> f64 {
    let mut j = 0.0;
    for x in 0..k {
        let row = d.row(sigma[x]);
        for y in 0..k {
            let w = bmat[x * k + y];
            if w != 0.0 {
                j += w * row.get(sigma[y]);
            }
        }
    }
    j
}

/// Validate a mapping: right length, all PEs in range.
pub fn validate_mapping(part: &[Block], n: usize, k: usize) -> Result<(), String> {
    if part.len() != n {
        return Err(format!("mapping length {} != n {}", part.len(), n));
    }
    if let Some(&b) = part.iter().find(|&&b| b as usize >= k) {
        return Err(format!("PE id {b} out of range (k={k})"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::EdgeList;

    fn h() -> Machine {
        Machine::hier("2:2", "1:10").unwrap()
    }

    #[test]
    fn l_max_formula() {
        assert_eq!(l_max(100, 4, 0.03), 26);
        assert_eq!(l_max(100, 3, 0.0), 34);
    }

    #[test]
    fn edge_cut_path_graph() {
        // Path 0-1-2-3 split [0,0,1,1]: one cut edge.
        let g = gen::grid2d(4, 1, false);
        let part = vec![0, 0, 1, 1];
        assert_eq!(edge_cut(&g, &part), 1.0);
    }

    #[test]
    fn comm_cost_respects_distance() {
        let g = gen::grid2d(4, 1, false);
        // PEs 0 and 1 share a processor (d=1); PEs 0 and 2 don't (d=10).
        let near = vec![0, 0, 1, 1];
        let far = vec![0, 0, 2, 2];
        let hh = h();
        // One cut edge, counted in both directions: J = 2·w·d.
        assert_eq!(comm_cost(&g, &near, &hh), 2.0);
        assert_eq!(comm_cost(&g, &far, &hh), 20.0);
    }

    #[test]
    fn comm_cost_par_matches_serial() {
        let pool = Pool::new(2);
        let g = gen::rgg(800, 0.08, 5);
        let el = EdgeList::build(&g);
        let hh = Machine::hier("4:8:2", "1:10:100").unwrap();
        let part: Vec<Block> = (0..g.n()).map(|v| (v % hh.k()) as Block).collect();
        let a = comm_cost(&g, &part, &hh);
        let b = comm_cost_par(&pool, &g, &el.eu, &part, &hh);
        assert!((a - b).abs() < 1e-6 * a.abs().max(1.0));
    }

    #[test]
    fn block_matrix_consistent_with_j() {
        let g = gen::stencil9(20, 20, 1);
        let hh = Machine::hier("2:2", "1:10").unwrap();
        let k = hh.k();
        let part: Vec<Block> = (0..g.n()).map(|v| (v % k) as Block).collect();
        let bmat = block_comm_matrix(&g, &part, k);
        let sigma: Vec<Block> = (0..k as Block).collect();
        let j_blocks = comm_cost_blocks(&bmat, k, &sigma, &hh.oracle());
        let j_direct = comm_cost(&g, &part, &hh);
        assert!((j_blocks - j_direct).abs() < 1e-6 * j_direct.max(1.0));
    }

    #[test]
    fn comm_cost_agrees_across_machine_models() {
        // A torus and the equivalent file matrix must score any mapping
        // identically (partition/ is fully model-agnostic).
        let g = gen::stencil9(12, 12, 2);
        let torus = Machine::parse_spec("torus:2x2").unwrap();
        let filem = crate::topology::MatrixModel::from_text(
            "4\n0 1 1 2\n1 0 2 1\n1 2 0 1\n2 1 1 0\n",
            "inline",
        )
        .unwrap();
        let filem = Machine::from_model(filem).unwrap();
        let part: Vec<Block> = (0..g.n()).map(|v| (v % 4) as Block).collect();
        let a = comm_cost(&g, &part, &torus);
        let b = comm_cost(&g, &part, &filem);
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }

    #[test]
    fn balance_checks() {
        let g = gen::grid2d(10, 1, false);
        let balanced = (0..10).map(|v| (v % 2) as Block).collect::<Vec<_>>();
        let skewed = vec![0 as Block; 10];
        assert!(is_balanced(&g, &balanced, 2, 0.0));
        assert!(!is_balanced(&g, &skewed, 2, 0.5));
        assert!(imbalance(&g, &skewed, 2) > 0.9);
    }

    #[test]
    fn mapping_validation() {
        assert!(validate_mapping(&[0, 1, 2], 3, 3).is_ok());
        assert!(validate_mapping(&[0, 3], 2, 3).is_err());
        assert!(validate_mapping(&[0], 2, 3).is_err());
    }
}
