//! Consistent-hash ring for session-graph routing.
//!
//! Each node contributes [`HashRing::vnodes`] virtual points on a `u64`
//! ring; a key's owners are the first `r` *distinct* nodes clockwise
//! from the key's hash. The point positions depend only on the node
//! name, so adding or removing a node moves only the keys whose
//! clockwise walk crossed that node's points — the classic minimal
//! remapping property the cluster tier relies on to keep session
//! graphs pinned while the fleet changes shape.
//!
//! The ring is a routing table, not a membership service: health lives
//! in [`super::node::Node`], and the router skips unhealthy owners at
//! dispatch time rather than mutating the ring (so a node coming back
//! up owns its old keys again without any remapping).

/// Virtual points per node. High enough that 8 nodes keep their key
/// shares within 2× of each other (property-tested below), low enough
/// that rebuilds stay trivial for fleet sizes the router targets.
pub const DEFAULT_VNODES: usize = 128;

/// FNV-1a over the bytes, finished with a SplitMix64 scramble so short
/// keys with shared prefixes still spread over the whole ring.
fn hash_key(s: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    crate::rng::splitmix64(&mut h)
}

/// A consistent-hash ring over named nodes.
#[derive(Clone, Debug, Default)]
pub struct HashRing {
    /// Member node names, insertion-ordered (stable for rendering).
    nodes: Vec<String>,
    /// Ring points, sorted by hash: `(point_hash, index into nodes)`.
    points: Vec<(u64, usize)>,
    vnodes: usize,
}

impl HashRing {
    /// An empty ring with [`DEFAULT_VNODES`] points per node.
    pub fn new() -> HashRing {
        HashRing::with_vnodes(DEFAULT_VNODES)
    }

    /// An empty ring with `vnodes` points per node (min 1).
    pub fn with_vnodes(vnodes: usize) -> HashRing {
        HashRing { nodes: Vec::new(), points: Vec::new(), vnodes: vnodes.max(1) }
    }

    /// Member count.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Member node names, in insertion order.
    pub fn nodes(&self) -> &[String] {
        &self.nodes
    }

    /// Add a node; false if it is already a member.
    pub fn add(&mut self, node: &str) -> bool {
        if self.nodes.iter().any(|n| n == node) {
            return false;
        }
        self.nodes.push(node.to_string());
        self.rebuild();
        true
    }

    /// Remove a node; false if it was not a member.
    pub fn remove(&mut self, node: &str) -> bool {
        let Some(pos) = self.nodes.iter().position(|n| n == node) else {
            return false;
        };
        self.nodes.remove(pos);
        self.rebuild();
        true
    }

    /// Point positions depend only on `(node name, replica index)`, so a
    /// full rebuild reproduces every surviving node's points exactly —
    /// membership changes move only the departed/arrived points.
    fn rebuild(&mut self) {
        self.points.clear();
        self.points.reserve(self.nodes.len() * self.vnodes);
        for (i, node) in self.nodes.iter().enumerate() {
            for v in 0..self.vnodes {
                self.points.push((hash_key(&format!("{node}#{v}")), i));
            }
        }
        // Ties (hash collisions across nodes) break by node index, which
        // is insertion order — deterministic for a fixed member sequence.
        self.points.sort_unstable();
    }

    /// The first `r` distinct nodes clockwise from `key`'s hash — the
    /// key's replica set, primary first. Fewer than `r` members yields
    /// every member (still primary-first).
    pub fn owners(&self, key: &str, r: usize) -> Vec<&str> {
        let want = r.max(1).min(self.nodes.len());
        let mut out: Vec<&str> = Vec::with_capacity(want);
        if self.points.is_empty() {
            return out;
        }
        let h = hash_key(key);
        let start = self.points.partition_point(|&(p, _)| p < h);
        for step in 0..self.points.len() {
            let (_, idx) = self.points[(start + step) % self.points.len()];
            let name = self.nodes[idx].as_str();
            if !out.contains(&name) {
                out.push(name);
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }

    /// The key's primary owner (first ring owner), if the ring has any
    /// member.
    pub fn primary(&self, key: &str) -> Option<&str> {
        self.owners(key, 1).first().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn ring_of(n: usize) -> HashRing {
        let mut ring = HashRing::new();
        for i in 0..n {
            ring.add(&format!("127.0.0.1:{}", 9000 + i));
        }
        ring
    }

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("session-graph-{i}")).collect()
    }

    #[test]
    fn membership_round_trips() {
        let mut ring = HashRing::new();
        assert!(ring.is_empty());
        assert!(ring.primary("x").is_none());
        assert!(ring.add("a"));
        assert!(!ring.add("a"), "duplicate add");
        assert!(ring.add("b"));
        assert_eq!(ring.len(), 2);
        assert_eq!(ring.nodes(), ["a".to_string(), "b".to_string()]);
        assert!(ring.remove("a"));
        assert!(!ring.remove("a"), "double remove");
        assert_eq!(ring.owners("anything", 3), vec!["b"]);
    }

    #[test]
    fn owners_are_deterministic() {
        let ring = ring_of(5);
        for key in keys(50) {
            assert_eq!(ring.owners(&key, 3), ring.owners(&key, 3));
        }
        // Rebuilding the same membership reproduces the same routing.
        let again = ring_of(5);
        for key in keys(50) {
            assert_eq!(ring.owners(&key, 3), again.owners(&key, 3));
        }
    }

    /// Property (balance): across 8 nodes, the largest primary key share
    /// stays within 2× of the smallest.
    #[test]
    fn key_shares_stay_balanced_across_eight_nodes() {
        let ring = ring_of(8);
        let mut share: BTreeMap<String, usize> = BTreeMap::new();
        let keys = keys(16_000);
        for key in &keys {
            *share.entry(ring.primary(key).unwrap().to_string()).or_insert(0) += 1;
        }
        assert_eq!(share.len(), 8, "every node must own some keys");
        let max = *share.values().max().unwrap();
        let min = *share.values().min().unwrap();
        assert!(
            max <= 2 * min,
            "imbalanced shares: max {max} > 2 × min {min} ({share:?})"
        );
    }

    /// Property (minimal remapping, join): adding a node to an N−1 ring
    /// moves at most 2/N of the primary assignments.
    #[test]
    fn node_join_moves_few_keys() {
        let before = ring_of(8);
        let mut after = before.clone();
        after.add("127.0.0.1:9999");
        let keys = keys(16_000);
        let moved = keys
            .iter()
            .filter(|k| before.primary(k) != after.primary(k))
            .count();
        let bound = keys.len() * 2 / after.len();
        assert!(moved <= bound, "join moved {moved} keys > bound {bound}");
        // Every moved key must have moved *to* the new node — nothing
        // shuffles between survivors.
        for k in &keys {
            if before.primary(k) != after.primary(k) {
                assert_eq!(after.primary(k), Some("127.0.0.1:9999"), "{k} moved sideways");
            }
        }
        assert!(moved > 0, "the new node must take some keys");
    }

    /// Property (minimal remapping, leave): removing one of N nodes moves
    /// at most 2/N of the primary assignments, and only the departed
    /// node's keys move.
    #[test]
    fn node_leave_moves_only_the_departed_nodes_keys() {
        let before = ring_of(8);
        let victim = "127.0.0.1:9003";
        let mut after = before.clone();
        after.remove(victim);
        let keys = keys(16_000);
        let mut moved = 0usize;
        for k in &keys {
            let was = before.primary(k).unwrap();
            let now = after.primary(k).unwrap();
            if was == victim {
                moved += 1;
                assert_ne!(now, victim);
            } else {
                assert_eq!(was, now, "{k}: survivor-owned key moved on leave");
            }
        }
        let bound = keys.len() * 2 / before.len();
        assert!(moved <= bound, "leave moved {moved} keys > bound {bound}");
        assert!(moved > 0, "the departed node owned no keys?");
    }

    /// Property (replica distinctness): the replica set never repeats a
    /// node and is capped by the membership size.
    #[test]
    fn replica_sets_are_distinct() {
        for members in [1usize, 2, 3, 8] {
            let ring = ring_of(members);
            for key in keys(500) {
                for r in 1..=4usize {
                    let owners = ring.owners(&key, r);
                    assert_eq!(owners.len(), r.min(members), "key {key} r {r}");
                    let mut dedup = owners.clone();
                    dedup.sort_unstable();
                    dedup.dedup();
                    assert_eq!(dedup.len(), owners.len(), "{key}: repeated replica");
                }
            }
        }
    }

    /// Replica sets are clockwise-stable: owners(key, 1) is a prefix of
    /// owners(key, 2), which is a prefix of owners(key, 3) — so bumping R
    /// only *adds* replicas, never re-homes a session.
    #[test]
    fn growing_r_extends_the_replica_set() {
        let ring = ring_of(6);
        for key in keys(200) {
            let three = ring.owners(&key, 3);
            assert_eq!(ring.owners(&key, 1), three[..1].to_vec());
            assert_eq!(ring.owners(&key, 2), three[..2].to_vec());
        }
    }
}
