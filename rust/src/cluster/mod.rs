//! Cluster tier: horizontal scale-out of the coordinator service.
//!
//! One [`Router`] process accepts the ordinary wire protocol
//! ([`crate::coordinator::protocol`]) and forwards every job to a fleet
//! of downstream `heipa serve` engine processes over TCP — the same
//! line protocol doubles as the inter-node transport, so a node needs
//! no cluster awareness beyond the (node-local) `ping`, `drain` and
//! `cluster …` verbs every coordinator already speaks.
//!
//! The pieces:
//!
//! - [`ring::HashRing`] — consistent hashing with virtual nodes routes
//!   `graph put`/`graph patch`/session `map`s to stable owners, pinning
//!   each session graph on a configurable number of replicas
//!   ([`RouterConfig::replication`]) with minimal remapping when the
//!   fleet changes shape.
//! - [`node::Node`] — one downstream process: pooled client
//!   connections, health from periodic typed `ping` probes *and* live
//!   traffic, and the queue-depth/in-flight gauges that drive
//!   least-loaded, backpressure-aware dispatch (`err code=busy` spills
//!   to the next candidate).
//! - [`Router`] — job-ID translation (router ↔ node), retained session
//!   graph copies, **failover** (a node dying mid-job re-homes the work
//!   onto a replica, re-uploading the graph, tagging replies
//!   `failover=1`), and fleet-aggregated `metrics` with the extra
//!   `routed_jobs`/`failovers`/`nodes_up` counters.
//!
//! Chaos hooks: the `route_dispatch` and `node_probe` fault points
//! ([`crate::fault::FaultPoint`]) sever links and lose probes
//! deterministically; under any seeding every job stays terminal —
//! a valid mapping or a typed error, never a hang.
//!
//! `heipa cluster` (see `main.rs`) spawns and supervises a local fleet
//! — router + N `serve` children — for tests and demos.

pub mod node;
pub mod ring;
pub mod router;

pub use node::{Health, Node};
pub use ring::HashRing;
pub use router::{serve_router, Router, RouterConfig};
