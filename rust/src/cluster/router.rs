//! The router coordinator: speaks the ordinary wire protocol on the
//! front, forwards every job to a fleet of downstream `serve` processes
//! on the back, and owns the cluster-wide state a single node cannot —
//! the consistent-hash ring, the retained session-graph copies that
//! make failover possible, and the router↔node job-ID translation.
//!
//! ## Routing
//! A `map`/`submit` whose `graph=` names a retained session graph goes
//! to the graph's ring owners (primary first, replicas next, then the
//! rest of the fleet by health and load); anonymous jobs go to the
//! least-loaded healthy node. A node answering `err code=busy` is soft
//! backpressure — the router moves to the next candidate.
//!
//! ## Failover
//! A transport error (connection drop, probe-detected death, or an
//! injected `route_dispatch` fault) fails the *candidate*, not the job:
//! the router re-sends to the next candidate, re-uploading the session
//! graph from its retained copy (`graph put` + every `graph patch`, in
//! order) when the replacement node does not hold it. Replies for work
//! that survived a failover carry `failover=1`, and the aggregated
//! `metrics` line counts `routed_jobs`/`failovers`/`nodes_up`.

use super::node::{Health, Node};
use super::ring::HashRing;
use crate::coordinator::protocol::{
    parse_command, render_err, render_error, serve_lines, Command, LineHandler, ServeOptions,
};
use crate::fault::{self, FaultPlane, FaultPoint};
use anyhow::Result;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

/// Router construction parameters.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Ring replication factor: a session graph is pinned on this many
    /// nodes (capped by the fleet size).
    pub replication: usize,
    /// Per-request socket timeout in ms (connect, read, write). Bounds
    /// how long one blocking `map`/`wait` can hold a router connection.
    pub request_timeout_ms: u64,
    /// Injectable fault plane for the `route_dispatch`/`node_probe`
    /// points (tests); the process-global `HEIPA_FAULTS` plane is
    /// always consulted as well.
    pub plane: Option<FaultPlane>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { replication: 2, request_timeout_ms: 120_000, plane: None }
    }
}

/// The router's retained copy of a session graph — enough to rebuild it
/// on any node: the original `graph put` line plus every accepted
/// `graph patch` line, in order.
struct GraphRecord {
    put_line: String,
    patches: Vec<String>,
    /// Router-side version: 1 on put, +1 per accepted patch or re-put.
    version: u64,
    /// Nodes known to hold the current version; anyone else gets a full
    /// re-upload before serving this session.
    synced: BTreeSet<String>,
}

/// Where a router job lives right now.
#[derive(Clone)]
struct JobRoute {
    node: String,
    node_job: u64,
    /// The original submit line — replayed on a replacement node when
    /// the owning node dies before the job is retired.
    submit_line: String,
    /// Session graph the job maps (drives re-upload on failover).
    graph: Option<String>,
    /// The job survived at least one failover; replies carry
    /// `failover=1`.
    failover: bool,
}

/// Tracked router-side jobs/batches; the oldest ids are evicted beyond
/// these (evicted ids answer `unknown_job`/`unknown_batch`).
const JOB_RETENTION: usize = 4096;
const BATCH_RETENTION: usize = 256;

/// The router coordinator. See the module docs for semantics.
pub struct Router {
    nodes: Vec<Arc<Node>>,
    ring: HashRing,
    replication: usize,
    graphs: Mutex<BTreeMap<String, GraphRecord>>,
    jobs: Mutex<BTreeMap<u64, JobRoute>>,
    job_seq: AtomicU64,
    /// Router batch id → (node addr, node batch id).
    batches: Mutex<BTreeMap<u64, (String, u64)>>,
    batch_seq: AtomicU64,
    routed_jobs: AtomicU64,
    failovers: AtomicU64,
    plane: Option<FaultPlane>,
}

/// `key=<u64>` token value from a reply line.
fn token_u64(reply: &str, key: &str) -> Option<u64> {
    reply.split_whitespace().find_map(|t| t.strip_prefix(key)?.parse().ok())
}

/// Rewrite one `key=<value>` token of a reply line (exact token prefix,
/// so `id=` never matches inside `job=`).
fn rewrite_token(reply: &str, key: &str, value: u64) -> String {
    let toks: Vec<String> = reply
        .split(' ')
        .map(|t| if t.starts_with(key) { format!("{key}{value}") } else { t.to_string() })
        .collect();
    toks.join(" ")
}

fn health_rank(h: Health) -> u8 {
    match h {
        Health::Up => 0,
        Health::Suspect => 1,
        Health::Down => 2,
    }
}

impl Router {
    /// A router over a fixed fleet of node addresses.
    pub fn new(addrs: &[String], cfg: RouterConfig) -> Router {
        let timeout = Duration::from_millis(cfg.request_timeout_ms.max(1));
        let mut ring = HashRing::new();
        let nodes: Vec<Arc<Node>> = addrs
            .iter()
            .map(|a| {
                ring.add(a);
                Arc::new(Node::new(a, timeout))
            })
            .collect();
        Router {
            nodes,
            ring,
            replication: cfg.replication.max(1),
            graphs: Mutex::new(BTreeMap::new()),
            jobs: Mutex::new(BTreeMap::new()),
            job_seq: AtomicU64::new(0),
            batches: Mutex::new(BTreeMap::new()),
            batch_seq: AtomicU64::new(0),
            routed_jobs: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            plane: cfg.plane,
        }
    }

    /// The node table, in fleet order.
    pub fn nodes(&self) -> &[Arc<Node>] {
        &self.nodes
    }

    /// Completed failovers so far.
    pub fn failovers(&self) -> u64 {
        // relaxed: monotone statistics counter.
        self.failovers.load(Ordering::Relaxed)
    }

    /// Jobs successfully forwarded so far.
    pub fn routed_jobs(&self) -> u64 {
        // relaxed: monotone statistics counter.
        self.routed_jobs.load(Ordering::Relaxed)
    }

    /// Start the background health-probe loop: every `interval`, each
    /// node gets a typed `ping` refreshing its health and load gauges.
    /// The loop holds only a weak reference and exits when the router is
    /// dropped.
    pub fn start_probes(self: &Arc<Self>, interval: Duration) {
        let weak = Arc::downgrade(self);
        let _ = std::thread::Builder::new().name("heipa-probe".into()).spawn(move || loop {
            std::thread::sleep(interval);
            let Some(router) = weak.upgrade() else { return };
            for node in &router.nodes {
                node.probe(router.plane.as_ref());
            }
        });
    }

    fn node(&self, addr: &str) -> Option<Arc<Node>> {
        self.nodes.iter().find(|n| n.addr() == addr).cloned()
    }

    /// Dispatch candidates for a job: the session graph's ring owners
    /// first (primary, then replicas), then every remaining node by
    /// (health, load). Down nodes rank last rather than never — a total
    /// blackout self-heals as soon as anything answers.
    fn candidates(&self, graph: Option<&str>) -> Vec<Arc<Node>> {
        let mut list: Vec<Arc<Node>> = Vec::new();
        if let Some(name) = graph {
            for addr in self.ring.owners(name, self.replication) {
                if let Some(n) = self.node(addr) {
                    list.push(n);
                }
            }
        }
        let mut rest: Vec<Arc<Node>> = self
            .nodes
            .iter()
            .filter(|n| !list.iter().any(|c| c.addr() == n.addr()))
            .cloned()
            .collect();
        rest.sort_by_key(|n| (health_rank(n.health()), n.load()));
        list.extend(rest);
        list
    }

    /// One request to one node, through the `route_dispatch` fault
    /// point. A transport error marks the node down (its probe revives
    /// it); an injected fault models a severed link and leaves the
    /// node's health untouched.
    fn send(&self, node: &Node, line: &str) -> std::io::Result<String> {
        if fault::fire(self.plane.as_ref(), FaultPoint::RouteDispatch) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                fault::failure(FaultPoint::RouteDispatch),
            ));
        }
        let reply = node.request(line);
        if reply.is_err() {
            node.mark_down();
        }
        reply
    }

    /// Push the retained copy of `name` to `node`: the stored `graph
    /// put` line, then every accepted patch, in order.
    fn resync_graph(&self, node: &Node, name: &str) -> std::io::Result<()> {
        let lines: Option<Vec<String>> = {
            let graphs = self.graphs.lock().unwrap_or_else(PoisonError::into_inner);
            graphs.get(name).map(|rec| {
                let mut ls = Vec::with_capacity(1 + rec.patches.len());
                ls.push(rec.put_line.clone());
                ls.extend(rec.patches.iter().cloned());
                ls
            })
        };
        let Some(lines) = lines else {
            return Err(std::io::Error::new(std::io::ErrorKind::NotFound, "graph not retained"));
        };
        for line in &lines {
            let reply = node.request(line)?;
            if !reply.starts_with("ok") {
                return Err(std::io::Error::other(format!("resync rejected: {reply}")));
            }
        }
        let mut graphs = self.graphs.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(rec) = graphs.get_mut(name) {
            rec.synced.insert(node.addr().to_string());
        }
        Ok(())
    }

    fn is_synced(&self, name: &str, addr: &str) -> bool {
        let graphs = self.graphs.lock().unwrap_or_else(PoisonError::into_inner);
        graphs.get(name).is_some_and(|rec| rec.synced.contains(addr))
    }

    fn graph_retained(&self, name: &str) -> bool {
        self.graphs.lock().unwrap_or_else(PoisonError::into_inner).contains_key(name)
    }

    /// Forward a job line to the first candidate that takes it,
    /// re-uploading the session graph where needed. `skip` excludes a
    /// node known-dead (the failover path). Returns the serving node,
    /// its reply, and whether any candidate had to be failed over.
    fn forward_job(
        &self,
        graph: Option<&str>,
        line: &str,
        skip: Option<&str>,
    ) -> std::result::Result<(Arc<Node>, String, bool), String> {
        let mut failed_over = false;
        let mut busy_reply: Option<String> = None;
        for node in self.candidates(graph) {
            if skip == Some(node.addr()) {
                continue;
            }
            // Proactive re-upload: a retained session graph the node does
            // not hold is pushed before the job lands on it.
            if let Some(name) = graph {
                if !self.is_synced(name, node.addr()) && self.resync_graph(&node, name).is_err() {
                    failed_over = true;
                    continue;
                }
            }
            match self.send(&node, line) {
                Ok(reply) if reply.starts_with("err code=busy") => {
                    // Backpressure, not failure: spill to the next node.
                    busy_reply.get_or_insert(reply);
                }
                Ok(reply) if reply.starts_with("err code=unknown_graph") && graph.is_some() => {
                    // Reactive safety net (a node lost state while marked
                    // synced): re-upload and retry this node once.
                    let name = graph.unwrap_or_default();
                    match self.resync_graph(&node, name).and_then(|()| self.send(&node, line)) {
                        Ok(retry) => return Ok((node, retry, failed_over)),
                        Err(_) => failed_over = true,
                    }
                }
                Ok(reply) => return Ok((node, reply, failed_over)),
                Err(_) => failed_over = true,
            }
        }
        Err(busy_reply
            .unwrap_or_else(|| render_err("unavailable", "no cluster node accepted the job")))
    }

    fn track_job(&self, route: JobRoute) -> u64 {
        // relaxed: monotone id allocator; the registry mutex below
        // orders the insert against lookups.
        let rid = self.job_seq.fetch_add(1, Ordering::Relaxed) + 1;
        let mut jobs = self.jobs.lock().unwrap_or_else(PoisonError::into_inner);
        jobs.insert(rid, route);
        while jobs.len() > JOB_RETENTION {
            jobs.pop_first();
        }
        rid
    }

    /// Re-home a job whose node died: replay the stored submit line on a
    /// replacement (re-uploading the session graph), update the route,
    /// and hand back the new node + node-side job id.
    fn failover_job(
        &self,
        rid: u64,
        route: &JobRoute,
    ) -> std::result::Result<(Arc<Node>, u64), String> {
        let (node, reply, _) =
            self.forward_job(route.graph.as_deref(), &route.submit_line, Some(&route.node))?;
        let Some(node_job) = token_u64(&reply, "job=") else {
            return Err(render_err("unavailable", &format!("failover resubmit got: {reply}")));
        };
        // relaxed: monotone statistics counter.
        self.failovers.fetch_add(1, Ordering::Relaxed);
        let mut jobs = self.jobs.lock().unwrap_or_else(PoisonError::into_inner);
        jobs.insert(
            rid,
            JobRoute {
                node: node.addr().to_string(),
                node_job,
                submit_line: route.submit_line.clone(),
                graph: route.graph.clone(),
                failover: true,
            },
        );
        Ok((node, node_job))
    }

    /// Run a job-scoped command (`status`/`wait`/`result`): forward to
    /// the owning node, fail the job over to a replacement when that
    /// node is gone, and translate ids in the reply.
    fn job_command(&self, rid: u64, make_line: impl Fn(u64) -> String) -> String {
        let route = {
            let jobs = self.jobs.lock().unwrap_or_else(PoisonError::into_inner);
            jobs.get(&rid).cloned()
        };
        let Some(route) = route else {
            return render_err("unknown_job", &format!("no job with id {rid}"));
        };
        let first = self
            .node(&route.node)
            .ok_or(())
            .and_then(|node| self.send(&node, &make_line(route.node_job)).map_err(|_| ()));
        let (reply, failover) = match first {
            Ok(reply) => (reply, route.failover),
            Err(()) => {
                // The owning node died with the job: re-submit elsewhere
                // and re-issue the command against the replacement.
                match self.failover_job(rid, &route) {
                    Err(e) => return e,
                    Ok((node, node_job)) => match self.send(&node, &make_line(node_job)) {
                        Ok(reply) => (reply, true),
                        Err(_) => {
                            return render_err(
                                "unavailable",
                                &format!("job {rid} lost its replacement node mid-command"),
                            )
                        }
                    },
                }
            }
        };
        let mut out = rewrite_token(&rewrite_token(&reply, "job=", rid), "id=", rid);
        if failover && out.starts_with("ok") {
            out.push_str(" failover=1");
        }
        out
    }

    /// Aggregate `metrics` across the fleet: numeric counters sum,
    /// `per_algorithm` maps merge, and the router appends its own
    /// `routed_jobs`/`failovers`/`nodes_up`.
    fn aggregate_metrics(&self) -> String {
        // Keys in the exact render order of
        // [`crate::coordinator::protocol::render_metrics`].
        const SUM_KEYS: &[&str] = &[
            "requests", "failures", "completed", "cancelled", "deadline_missed",
            "busy_rejections", "hier_hits", "hier_misses", "retries", "faults_injected",
            "degraded", "patches", "graphs_replaced", "warm_remaps", "cold_fallbacks",
            "batches", "batched_jobs", "device_launches", "h2d_bytes", "d2h_bytes",
            "backend_fallbacks", "queue_depth", "in_flight",
        ];
        let mut sums: BTreeMap<&str, u64> = BTreeMap::new();
        let (mut host_ms, mut device_ms) = (0.0f64, 0.0f64);
        let mut per: BTreeMap<String, u64> = BTreeMap::new();
        for node in &self.nodes {
            let Ok(reply) = self.send(node, "metrics") else { continue };
            for tok in reply.split_whitespace() {
                let Some((k, v)) = tok.split_once('=') else { continue };
                if let Some(key) = SUM_KEYS.iter().find(|&&s| s == k) {
                    *sums.entry(key).or_insert(0) += v.parse::<u64>().unwrap_or(0);
                } else if k == "host_ms" {
                    host_ms += v.parse::<f64>().unwrap_or(0.0);
                } else if k == "device_ms" {
                    device_ms += v.parse::<f64>().unwrap_or(0.0);
                } else if k == "per_algorithm" {
                    for entry in v.split(';').filter(|e| !e.is_empty()) {
                        if let Some((alg, count)) = entry.split_once(':') {
                            *per.entry(alg.to_string()).or_insert(0) +=
                                count.parse::<u64>().unwrap_or(0);
                        }
                    }
                }
            }
        }
        let mut out = String::from("ok");
        for key in SUM_KEYS {
            out.push_str(&format!(" {key}={}", sums.get(key).copied().unwrap_or(0)));
        }
        out.push_str(&format!(" host_ms={host_ms:.1} device_ms={device_ms:.1}"));
        let per_s: Vec<String> = per.iter().map(|(k, v)| format!("{k}:{v}")).collect();
        out.push_str(&format!(" per_algorithm={}", per_s.join(";")));
        let nodes_up = self.nodes.iter().filter(|n| n.health() == Health::Up).count();
        out.push_str(&format!(
            " routed_jobs={} failovers={} nodes_up={nodes_up}",
            self.routed_jobs(),
            self.failovers(),
        ));
        out
    }

    /// The session name a request routes by — its `graph=`/`instance=`
    /// when the router retains a graph of that name.
    fn session_of(&self, instance: &str) -> Option<String> {
        self.graph_retained(instance).then(|| instance.to_string())
    }

    /// Handle one wire line — the router's analogue of
    /// [`crate::coordinator::protocol::handle_command`].
    pub fn handle_line(&self, line: &str) -> String {
        match parse_command(line) {
            Err(e) => render_error(&e),
            Ok(cmd) => self.dispatch(line, cmd),
        }
    }

    fn dispatch(&self, line: &str, cmd: Command) -> String {
        match cmd {
            Command::Ping => {
                let (qd, inf) = self
                    .nodes
                    .iter()
                    .fold((0, 0), |(q, f), n| (q + n.queue_depth(), f + n.in_flight()));
                let graphs = self.graphs.lock().unwrap_or_else(PoisonError::into_inner).len();
                let up = self.nodes.iter().filter(|n| n.health() == Health::Up).count();
                format!(
                    "ok version={} queue_depth={qd} in_flight={inf} graphs={graphs} \
                     nodes={} nodes_up={up}",
                    env!("CARGO_PKG_VERSION"),
                    self.nodes.len(),
                )
            }
            Command::Metrics => self.aggregate_metrics(),
            Command::ClusterNodes => {
                let list: Vec<String> = self
                    .nodes
                    .iter()
                    .map(|n| {
                        format!(
                            "{}/{}/{}/{}",
                            n.addr(),
                            n.health().name(),
                            n.queue_depth(),
                            n.in_flight()
                        )
                    })
                    .collect();
                format!("ok count={} nodes={}", self.nodes.len(), list.join(","))
            }
            Command::ClusterRoute { name } => {
                if !self.graph_retained(&name) {
                    return render_err("unknown_graph", &format!("no pinned graph named {name}"));
                }
                let owners: Vec<&str> = self.ring.owners(&name, self.replication);
                format!("ok graph={name} owners={}", owners.join(","))
            }
            Command::Drain { .. } => {
                // Fleet-wide drain; unreachable nodes have nothing left
                // to drain.
                for node in &self.nodes {
                    match self.send(node, line) {
                        Ok(reply) if reply.starts_with("ok") => {}
                        Ok(reply) => return reply,
                        Err(_) => {}
                    }
                }
                "ok drained=1".to_string()
            }
            Command::Map { ref req, .. } => {
                let graph = self.session_of(&req.instance);
                match self.forward_job(graph.as_deref(), line, None) {
                    Err(e) => e,
                    Ok((_, reply, failed_over)) => {
                        if !reply.starts_with("ok") {
                            return reply;
                        }
                        // relaxed: monotone statistics counter.
                        self.routed_jobs.fetch_add(1, Ordering::Relaxed);
                        if failed_over {
                            // relaxed: monotone statistics counter.
                            self.failovers.fetch_add(1, Ordering::Relaxed);
                        }
                        // A blocking map is retired by the time it
                        // replies: allocate a router id for the reply but
                        // keep it out of the route table (a later
                        // `status` answers `unknown_job`, as for any
                        // retired-and-evicted job).
                        // relaxed: monotone id allocator.
                        let rid = self.job_seq.fetch_add(1, Ordering::Relaxed) + 1;
                        let mut out = rewrite_token(&reply, "id=", rid);
                        if failed_over {
                            out.push_str(" failover=1");
                        }
                        out
                    }
                }
            }
            Command::Submit { ref req, .. } => {
                let graph = self.session_of(&req.instance);
                match self.forward_job(graph.as_deref(), line, None) {
                    Err(e) => e,
                    Ok((node, reply, failed_over)) => {
                        let Some(node_job) = token_u64(&reply, "job=") else {
                            return reply; // typed node-side error
                        };
                        // relaxed: monotone statistics counter.
                        self.routed_jobs.fetch_add(1, Ordering::Relaxed);
                        if failed_over {
                            // relaxed: monotone statistics counter.
                            self.failovers.fetch_add(1, Ordering::Relaxed);
                        }
                        let rid = self.track_job(JobRoute {
                            node: node.addr().to_string(),
                            node_job,
                            submit_line: line.to_string(),
                            graph,
                            failover: failed_over,
                        });
                        let mut out = rewrite_token(&reply, "job=", rid);
                        if failed_over {
                            out.push_str(" failover=1");
                        }
                        out
                    }
                }
            }
            Command::Status { job } => self.job_command(job, |nid| format!("status job={nid}")),
            Command::Wait { job, timeout_ms } => self.job_command(job, |nid| match timeout_ms {
                Some(ms) => format!("wait job={nid} timeout_ms={ms}"),
                None => format!("wait job={nid}"),
            }),
            Command::JobResult { job } => self.job_command(job, |nid| format!("result job={nid}")),
            Command::Cancel { job } => {
                let route = {
                    let jobs = self.jobs.lock().unwrap_or_else(PoisonError::into_inner);
                    jobs.get(&job).cloned()
                };
                let Some(route) = route else {
                    return render_err("unknown_job", &format!("no job with id {job}"));
                };
                let sent = self.node(&route.node).ok_or(()).and_then(|n| {
                    self.send(&n, &format!("cancel job={}", route.node_job)).map_err(|_| ())
                });
                match sent {
                    Ok(reply) => rewrite_token(&reply, "job=", job),
                    // The job died with its node; cancel's goal is met.
                    Err(()) => format!("ok job={job} cancelled=1 state=cancelled"),
                }
            }
            Command::Jobs => {
                let routes: Vec<(u64, JobRoute)> = {
                    let jobs = self.jobs.lock().unwrap_or_else(PoisonError::into_inner);
                    jobs.iter().map(|(k, v)| (*k, v.clone())).collect()
                };
                if routes.is_empty() {
                    return "ok count=0".to_string();
                }
                let list: Vec<String> = routes
                    .iter()
                    .map(|(rid, route)| {
                        let state = self
                            .node(&route.node)
                            .and_then(|n| {
                                self.send(&n, &format!("status job={}", route.node_job)).ok()
                            })
                            .and_then(|r| {
                                r.split_whitespace()
                                    .find_map(|t| t.strip_prefix("state=").map(str::to_string))
                            })
                            .unwrap_or_else(|| "lost".to_string());
                        format!("{rid}:{state}")
                    })
                    .collect();
                format!("ok count={} jobs={}", routes.len(), list.join(","))
            }
            Command::GraphPut { ref name, .. } => self.graph_put(name, line),
            Command::GraphPatch { ref name, .. } => self.graph_patch(name, line),
            Command::GraphList => {
                let graphs = self.graphs.lock().unwrap_or_else(PoisonError::into_inner);
                if graphs.is_empty() {
                    return "ok count=0".to_string();
                }
                let list: Vec<String> =
                    graphs.iter().map(|(n, r)| format!("{n}@v{}", r.version)).collect();
                format!("ok count={} graphs={}", graphs.len(), list.join(","))
            }
            Command::GraphDrop { ref name, .. } => {
                let existed = {
                    let mut graphs = self.graphs.lock().unwrap_or_else(PoisonError::into_inner);
                    graphs.remove(name.as_str()).is_some()
                };
                if !existed {
                    return render_err("unknown_graph", &format!("no pinned graph named {name}"));
                }
                // Best-effort fleet-wide drop; a node that never held the
                // graph answers unknown_graph, which is fine.
                for node in &self.nodes {
                    let _ = self.send(node, line);
                }
                format!("ok dropped={name}")
            }
            Command::BatchSubmit { ref reqs, .. } => {
                let graph = reqs.first().and_then(|r| self.session_of(&r.instance));
                match self.forward_job(graph.as_deref(), line, None) {
                    Err(e) => e,
                    Ok((node, reply, failed_over)) => {
                        let Some(node_batch) = token_u64(&reply, "batch=") else {
                            return reply; // typed node-side error
                        };
                        let node_jobs: Vec<u64> = reply
                            .split_whitespace()
                            .find_map(|t| t.strip_prefix("jobs="))
                            .map(|list| list.split(',').filter_map(|v| v.parse().ok()).collect())
                            .unwrap_or_default();
                        // relaxed: monotone statistics counter.
                        self.routed_jobs.fetch_add(node_jobs.len() as u64, Ordering::Relaxed);
                        if failed_over {
                            // relaxed: monotone statistics counter.
                            self.failovers.fetch_add(1, Ordering::Relaxed);
                        }
                        let rids: Vec<u64> = node_jobs
                            .iter()
                            .map(|&nid| {
                                self.track_job(JobRoute {
                                    node: node.addr().to_string(),
                                    node_job: nid,
                                    submit_line: String::new(), // batch jobs re-home as a unit
                                    graph: graph.clone(),
                                    failover: failed_over,
                                })
                            })
                            .collect();
                        let rbatch = {
                            // relaxed: monotone id allocator; the registry
                            // mutex below orders the insert.
                            let id = self.batch_seq.fetch_add(1, Ordering::Relaxed) + 1;
                            let mut batches =
                                self.batches.lock().unwrap_or_else(PoisonError::into_inner);
                            batches.insert(id, (node.addr().to_string(), node_batch));
                            while batches.len() > BATCH_RETENTION {
                                batches.pop_first();
                            }
                            id
                        };
                        let ids: Vec<String> = rids.iter().map(|r| r.to_string()).collect();
                        let mut out = format!(
                            "ok batch={rbatch} count={} jobs={}",
                            rids.len(),
                            ids.join(",")
                        );
                        if failed_over {
                            out.push_str(" failover=1");
                        }
                        out
                    }
                }
            }
            Command::BatchWait { id, timeout_ms } => {
                let target = {
                    let batches = self.batches.lock().unwrap_or_else(PoisonError::into_inner);
                    batches.get(&id).cloned()
                };
                let Some((addr, node_batch)) = target else {
                    return render_err("unknown_batch", &format!("no batch with id {id}"));
                };
                let wire = match timeout_ms {
                    Some(ms) => format!("batch wait id={node_batch} timeout_ms={ms}"),
                    None => format!("batch wait id={node_batch}"),
                };
                match self.node(&addr).ok_or(()).and_then(|n| self.send(&n, &wire).map_err(|_| ()))
                {
                    Ok(reply) => rewrite_token(&reply, "batch=", id),
                    Err(()) => render_err(
                        "unavailable",
                        &format!("batch {id} lost its node; batch jobs do not re-home"),
                    ),
                }
            }
        }
    }

    /// `graph put`: pin the session on its ring owners, retain the put
    /// line for failover re-uploads. At least one owner must accept.
    fn graph_put(&self, name: &str, line: &str) -> String {
        let owners: Vec<String> =
            self.ring.owners(name, self.replication).iter().map(|s| s.to_string()).collect();
        let mut ok_reply: Option<String> = None;
        let mut err_reply: Option<String> = None;
        let mut synced = BTreeSet::new();
        for addr in &owners {
            let Some(node) = self.node(addr) else { continue };
            match self.send(&node, line) {
                Ok(reply) if reply.starts_with("ok") => {
                    synced.insert(addr.clone());
                    ok_reply.get_or_insert(reply);
                }
                Ok(reply) => {
                    err_reply.get_or_insert(reply);
                }
                Err(_) => {}
            }
        }
        let Some(reply) = ok_reply else {
            return err_reply
                .unwrap_or_else(|| render_err("unavailable", "no graph owner reachable"));
        };
        let mut graphs = self.graphs.lock().unwrap_or_else(PoisonError::into_inner);
        let (version, replaced) = match graphs.get(name) {
            Some(prev) => (prev.version + 1, true),
            None => (1, false),
        };
        graphs.insert(
            name.to_string(),
            GraphRecord { put_line: line.to_string(), patches: Vec::new(), version, synced },
        );
        let n = token_u64(&reply, "n=").unwrap_or(0);
        let m = token_u64(&reply, "m=").unwrap_or(0);
        let mut out = format!("ok graph={name} n={n} m={m} version={version}");
        if replaced {
            out.push_str(" replaced=1");
        }
        out
    }

    /// `graph patch`: apply on every synced owner (resyncing stragglers
    /// first), retain the patch line on success.
    fn graph_patch(&self, name: &str, line: &str) -> String {
        if !self.graph_retained(name) {
            return render_err("unknown_graph", &format!("no pinned graph named {name}"));
        }
        let owners: Vec<String> =
            self.ring.owners(name, self.replication).iter().map(|s| s.to_string()).collect();
        let mut ok_reply: Option<String> = None;
        let mut err_reply: Option<String> = None;
        let mut appliers = BTreeSet::new();
        for addr in &owners {
            let Some(node) = self.node(addr) else { continue };
            let sent = if self.is_synced(name, addr) {
                self.send(&node, line)
            } else {
                self.resync_graph(&node, name).and_then(|()| self.send(&node, line))
            };
            match sent {
                Ok(reply) if reply.starts_with("ok") => {
                    appliers.insert(addr.clone());
                    ok_reply.get_or_insert(reply);
                }
                Ok(reply) => {
                    err_reply.get_or_insert(reply);
                }
                Err(_) => {}
            }
        }
        let mut graphs = self.graphs.lock().unwrap_or_else(PoisonError::into_inner);
        let Some(rec) = graphs.get_mut(name) else {
            return render_err("unknown_graph", &format!("no pinned graph named {name}"));
        };
        match ok_reply {
            Some(reply) => {
                rec.version += 1;
                rec.patches.push(line.to_string());
                rec.synced = appliers;
                rewrite_token(&reply, "version=", rec.version)
            }
            None => {
                // Nothing applied: the record is unchanged, so synced
                // nodes stay synced.
                err_reply.unwrap_or_else(|| render_err("unavailable", "no graph owner reachable"))
            }
        }
    }
}

/// Bind `addr`, print the bound address, and serve the router forever.
/// The accept loop is the shared [`serve_lines`], so connection caps,
/// line bounds and the wire fault points behave exactly as on a node.
pub fn serve_router(router: Arc<Router>, addr: &str, opts: ServeOptions) -> Result<()> {
    let listener = std::net::TcpListener::bind(addr)?;
    println!("heipa router listening on {}", listener.local_addr()?);
    let handler: LineHandler = Arc::new(move |line| router.handle_line(line));
    serve_lines(listener, opts, handler)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_helpers_rewrite_exact_keys_only() {
        assert_eq!(token_u64("ok job=17 state=queued", "job="), Some(17));
        assert_eq!(token_u64("ok id=3 j=120.0", "id="), Some(3));
        assert_eq!(token_u64("ok state=done", "job="), None);
        // `id=` must not match inside `job=` (token prefix, not substr).
        assert_eq!(rewrite_token("ok job=17 id=17", "id=", 2), "ok job=17 id=2");
        assert_eq!(rewrite_token("ok job=17 state=queued", "job=", 5), "ok job=5 state=queued");
        // Unrelated tokens pass through untouched.
        assert_eq!(rewrite_token("ok mapping=1,2,3", "id=", 9), "ok mapping=1,2,3");
    }

    #[test]
    fn unknown_ids_are_typed_errors() {
        let router = Router::new(&[], RouterConfig::default());
        assert!(router.handle_line("status job=1").starts_with("err code=unknown_job"));
        assert!(router.handle_line("cancel job=1").starts_with("err code=unknown_job"));
        assert!(router.handle_line("batch wait id=1").starts_with("err code=unknown_batch"));
        assert!(router
            .handle_line("cluster route name=x")
            .starts_with("err code=unknown_graph"));
        assert_eq!(router.handle_line("jobs"), "ok count=0");
        assert_eq!(router.handle_line("graph list"), "ok count=0");
        // Garbage still parses to a typed reply through the shared parser.
        assert!(router.handle_line("frob").starts_with("err code=parse"));
    }

    #[test]
    fn empty_fleet_reports_unavailable_not_hangs() {
        let router = Router::new(&[], RouterConfig::default());
        let reply = router.handle_line("map instance=wal_598a hierarchy=2:2 distance=1:10");
        assert!(reply.starts_with("err code=unavailable"), "{reply}");
        let reply = router.handle_line("graph put name=t csr=0,2,4,6/1,2,0,2,0,1");
        assert!(reply.starts_with("err code=unavailable"), "{reply}");
        // Aggregated metrics over zero nodes still render every key.
        let m = router.handle_line("metrics");
        assert!(m.starts_with("ok requests=0"), "{m}");
        assert!(m.contains(" routed_jobs=0 failovers=0 nodes_up=0"), "{m}");
    }

    #[test]
    fn dead_fleet_fails_over_to_unavailable() {
        // Two unreachable addrs: every candidate fails, the job is
        // terminal (typed error), never hung.
        let cfg = RouterConfig { request_timeout_ms: 100, ..RouterConfig::default() };
        let router = Router::new(&["127.0.0.1:1".into(), "127.0.0.1:2".into()], cfg);
        let reply = router.handle_line("map instance=wal_598a hierarchy=2:2 distance=1:10");
        assert!(reply.starts_with("err code=unavailable"), "{reply}");
        assert_eq!(router.routed_jobs(), 0);
        assert!(router.nodes().iter().all(|n| n.health() == Health::Down));
    }
}
