//! One downstream engine process as the router sees it: a pooled client
//! connection, health tracked by probes *and* live traffic, and the
//! load gauges that drive least-loaded dispatch.
//!
//! Health transitions: a successful request or probe marks the node
//! `Up` and resets the failure streak; a probe failure marks it
//! `Suspect`, and a second consecutive failure (or a transport error on
//! a live request, via [`Node::mark_down`]) marks it `Down`. Down nodes
//! are deprioritized — not excluded — by the router, so a total
//! blackout self-heals as soon as any node answers again.

use crate::fault::{self, FaultPlane, FaultPoint};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, PoisonError};
use std::time::Duration;

/// Node health as seen by the router.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Answering probes/requests.
    Up,
    /// One probe failure; still tried, watched closely.
    Suspect,
    /// Repeated probe failures or a mid-request transport error.
    Down,
}

impl Health {
    pub fn name(self) -> &'static str {
        match self {
            Health::Up => "up",
            Health::Suspect => "suspect",
            Health::Down => "down",
        }
    }

    fn from_u8(v: u8) -> Health {
        match v {
            0 => Health::Up,
            1 => Health::Suspect,
            _ => Health::Down,
        }
    }
}

/// A checked-in client connection. The protocol is strictly one request
/// → one reply, so after a full reply line the stream is quiescent and
/// safe to pool (no half-read bytes can be stranded in the reader).
struct PooledConn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

/// One downstream engine process.
pub struct Node {
    addr: String,
    /// [`Health`] as its `u8` discriminant.
    health: AtomicU8,
    /// Probe failures since the last success; 2 in a row → `Down`.
    consecutive_failures: AtomicU32,
    /// Last probed queue depth / in-flight count (backpressure gauges).
    queue_depth: AtomicU64,
    in_flight: AtomicU64,
    pool: Mutex<Vec<PooledConn>>,
    timeout: Duration,
}

/// Connections pooled per node; excess check-ins just close.
const POOL_CAP: usize = 4;

impl Node {
    pub fn new(addr: &str, timeout: Duration) -> Node {
        Node {
            addr: addr.to_string(),
            health: AtomicU8::new(Health::Up as u8),
            consecutive_failures: AtomicU32::new(0),
            queue_depth: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            pool: Mutex::new(Vec::new()),
            timeout,
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn health(&self) -> Health {
        // relaxed: health is an advisory routing hint; a stale read only
        // costs one misrouted attempt, which the failover loop absorbs.
        Health::from_u8(self.health.load(Ordering::Relaxed))
    }

    /// Backpressure score for least-loaded dispatch (lower is better).
    pub fn load(&self) -> u64 {
        // relaxed: advisory gauges refreshed by the probe loop; dispatch
        // only needs a roughly current ordering across nodes.
        self.queue_depth.load(Ordering::Relaxed) + self.in_flight.load(Ordering::Relaxed)
    }

    /// Last probed queue depth (wire `cluster nodes` rendering).
    pub fn queue_depth(&self) -> u64 {
        // relaxed: advisory gauge.
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Last probed in-flight count (wire `cluster nodes` rendering).
    pub fn in_flight(&self) -> u64 {
        // relaxed: advisory gauge.
        self.in_flight.load(Ordering::Relaxed)
    }

    /// A transport error on a live request: the node is gone right now.
    pub fn mark_down(&self) {
        // relaxed: advisory routing hint (see `health`).
        self.health.store(Health::Down as u8, Ordering::Relaxed);
        self.pool.lock().unwrap_or_else(PoisonError::into_inner).clear();
    }

    fn mark_up(&self) {
        // relaxed: advisory routing hint (see `health`).
        self.health.store(Health::Up as u8, Ordering::Relaxed);
        self.consecutive_failures.store(0, Ordering::Relaxed);
    }

    fn probe_failed(&self) {
        // relaxed: the failure streak is only consulted by the single
        // probe thread that also bumps it; health is advisory.
        let streak = self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        let next = if streak >= 2 { Health::Down } else { Health::Suspect };
        self.health.store(next as u8, Ordering::Relaxed);
        if next == Health::Down {
            self.pool.lock().unwrap_or_else(PoisonError::into_inner).clear();
        }
    }

    fn connect(&self) -> std::io::Result<PooledConn> {
        use std::net::ToSocketAddrs;
        let addr = self
            .addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::NotFound, "unresolvable addr"))?;
        let stream = TcpStream::connect_timeout(&addr, self.timeout.max(Duration::from_millis(1)))?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(PooledConn { writer: stream, reader })
    }

    fn roundtrip(conn: &mut PooledConn, line: &str) -> std::io::Result<String> {
        conn.writer.write_all(line.as_bytes())?;
        conn.writer.write_all(b"\n")?;
        let mut reply = String::new();
        let n = conn.reader.read_line(&mut reply)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed before reply",
            ));
        }
        while reply.ends_with('\n') || reply.ends_with('\r') {
            reply.pop();
        }
        Ok(reply)
    }

    fn check_in(&self, conn: PooledConn) {
        let mut pool = self.pool.lock().unwrap_or_else(PoisonError::into_inner);
        if pool.len() < POOL_CAP {
            pool.push(conn);
        }
    }

    /// One request → one reply over a pooled connection. A stale pooled
    /// socket (peer restarted since check-in) gets one fresh-dial retry
    /// before the error surfaces; a surfaced error means the node is
    /// unreachable *now* and the caller should fail over.
    pub fn request(&self, line: &str) -> std::io::Result<String> {
        let pooled = self.pool.lock().unwrap_or_else(PoisonError::into_inner).pop();
        if let Some(mut conn) = pooled {
            if let Ok(reply) = Node::roundtrip(&mut conn, line) {
                self.check_in(conn);
                self.mark_up();
                return Ok(reply);
            }
            // Stale pooled socket — fall through to a fresh dial.
        }
        let mut conn = self.connect()?;
        let reply = Node::roundtrip(&mut conn, line)?;
        self.check_in(conn);
        self.mark_up();
        Ok(reply)
    }

    /// One health-probe round: a typed `ping`, refreshing the load
    /// gauges on success. `plane` is the router's injectable fault plane
    /// (the `node_probe` point models a lost probe). Returns whether the
    /// node answered.
    pub fn probe(&self, plane: Option<&FaultPlane>) -> bool {
        if fault::fire(plane, FaultPoint::NodeProbe) {
            self.probe_failed();
            return false;
        }
        match self.request("ping") {
            Ok(reply) if reply.starts_with("ok ") => {
                for tok in reply.split_whitespace() {
                    if let Some(v) = tok.strip_prefix("queue_depth=") {
                        if let Ok(d) = v.parse() {
                            // relaxed: advisory gauge (see `load`).
                            self.queue_depth.store(d, Ordering::Relaxed);
                        }
                    } else if let Some(v) = tok.strip_prefix("in_flight=") {
                        if let Ok(f) = v.parse() {
                            // relaxed: advisory gauge (see `load`).
                            self.in_flight.store(f, Ordering::Relaxed);
                        }
                    }
                }
                self.mark_up();
                true
            }
            _ => {
                self.probe_failed();
                false
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_degrades_suspect_then_down_and_recovers() {
        let node = Node::new("127.0.0.1:1", Duration::from_millis(50));
        assert_eq!(node.health(), Health::Up);
        node.probe_failed();
        assert_eq!(node.health(), Health::Suspect);
        node.probe_failed();
        assert_eq!(node.health(), Health::Down);
        node.mark_up();
        assert_eq!(node.health(), Health::Up);
        node.mark_down();
        assert_eq!(node.health(), Health::Down);
    }

    #[test]
    fn request_against_a_dead_addr_errors_fast() {
        // Port 1 on localhost refuses (or times out) immediately.
        let node = Node::new("127.0.0.1:1", Duration::from_millis(100));
        assert!(node.request("ping").is_err());
        assert!(!node.probe(None));
        assert_eq!(node.health(), Health::Suspect);
    }

    #[test]
    fn probe_round_trips_against_a_live_listener() {
        // A hand-rolled one-shot server speaking the typed ping reply.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            let mut line = String::new();
            while reader.read_line(&mut line).unwrap() > 0 {
                assert_eq!(line.trim(), "ping");
                writer
                    .write_all(b"ok version=test queue_depth=3 in_flight=2 graphs=0\n")
                    .unwrap();
                line.clear();
            }
        });
        let node = Node::new(&addr.to_string(), Duration::from_secs(5));
        assert!(node.probe(None));
        assert_eq!(node.health(), Health::Up);
        assert_eq!(node.load(), 5);
        assert_eq!((node.queue_depth(), node.in_flight()), (3, 2));
        // The pooled connection is reused for the next probe.
        assert!(node.probe(None));
        drop(node);
        server.join().unwrap();
    }

    #[test]
    fn armed_probe_plane_fails_probes_deterministically() {
        let mut plane = FaultPlane::disarmed();
        plane.arm(FaultPoint::NodeProbe, 1.0, 42);
        let node = Node::new("127.0.0.1:1", Duration::from_millis(50));
        assert!(!node.probe(Some(&plane)));
        assert_eq!(node.health(), Health::Suspect);
        assert!(!node.probe(Some(&plane)));
        assert_eq!(node.health(), Health::Down);
    }
}
