//! Deterministic fault-injection plane.
//!
//! A [`FaultPlane`] arms named injection points — [`FaultPoint`] — with a
//! probability and a seed; every armed check draws from a SplitMix64
//! stream keyed by `(seed, point, check index)`, so a given plane fires
//! at exactly the same checks on every run. Two planes exist:
//!
//! * the **process-global plane**, parsed once from the `HEIPA_FAULTS`
//!   environment variable (see [`FaultPlane::parse`] for the grammar) and
//!   consulted by the hot layers themselves — kernel launches
//!   ([`crate::par::Pool`]), multilevel hierarchy builds
//!   ([`crate::multilevel::CoarseHierarchy`]), METIS parsing
//!   ([`crate::graph::io`]) and the TCP accept loop
//!   ([`crate::coordinator::protocol::serve_listener`]);
//! * **per-job planes**, built by the engine from `__fault.*` spec
//!   options (`opt.__fault.solve=0.5`, `opt.__fault.seed=9` on the
//!   wire). Their check counters start at zero for every attempt (with
//!   the attempt number salted into the stream), so a job's fault
//!   sequence is bit-for-bit reproducible regardless of worker
//!   scheduling. See [`FaultPlane::from_options`].
//!
//! Injection semantics by point (who observes the failure is part of the
//! contract — the engine's panic fence turns every one into a clean
//! `Failed` attempt, never a dead worker):
//!
//! | point             | fires in                                   | failure mode |
//! |-------------------|--------------------------------------------|--------------|
//! | `kernel_launch`   | `Pool::parallel_for`/`reduce`/`scan`, pre-dispatch, submitting thread only | panic |
//! | `hierarchy_build` | each level of `CoarseHierarchy::build`/`build_serial`; per-job plane: before the engine's hierarchy step | panic |
//! | `graph_load`      | `graph::io::parse_metis` entry             | `Err`        |
//! | `graph_store`     | engine graph resolution (`resolve_graph`)  | `Err`        |
//! | `job_pickup`      | worker job pickup, before the solve        | `Err`        |
//! | `solve`           | engine `execute`, before the solver runs   | panic        |
//! | `wire_read`       | coordinator connection loop, before a read | connection closed |
//! | `wire_write`      | coordinator connection loop, before a reply| connection closed |
//! | `route_dispatch`  | cluster router, before forwarding a request to a node | `Err` (dispatch retried on a replica) |
//! | `node_probe`      | cluster health probe, before pinging a node | probe failure (node marked suspect) |
//! | `device_launch`   | real PJRT kernel execution ([`crate::runtime::device`]), per launch; also the engine's backend resolution | panic |
//!
//! Injected failures carry the [`INJECTED_MARKER`] substring in their
//! message, which is how the engine attributes them to its
//! `faults_injected` counter. The self-healing pipeline's fallback chain
//! runs under [`suppress`], which silences *every* plane on the current
//! thread so degradation can succeed even when the environment plane is
//! armed at probability 1.

use anyhow::{bail, Result};
use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Marker substring present in every injected failure message; the
/// engine uses it to tell injected faults apart from organic failures.
pub const INJECTED_MARKER: &str = "injected fault";

/// Named injection points of the fault plane. See the module docs for
/// where each one may fire and with which failure mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// Device kernel launch (`par::Pool` primitives), pre-dispatch.
    KernelLaunch,
    /// Multilevel hierarchy construction, per level.
    HierarchyBuild,
    /// METIS graph parsing/loading.
    GraphLoad,
    /// Engine graph-store resolution.
    GraphStore,
    /// Worker job pickup, before the solve starts.
    JobPickup,
    /// The solve itself (replaces the old ad-hoc `__panic` hook).
    Solve,
    /// Coordinator wire read.
    WireRead,
    /// Coordinator wire write.
    WireWrite,
    /// Cluster router, before forwarding a request to a node.
    RouteDispatch,
    /// Cluster health probe, before pinging a node.
    NodeProbe,
    /// Real PJRT device kernel execution, per launch (and the engine's
    /// device-backend resolution, once per job).
    DeviceLaunch,
}

/// Number of distinct fault points.
const POINTS: usize = 11;

impl FaultPoint {
    /// All points, in a fixed order (`all` in the `HEIPA_FAULTS` grammar
    /// expands to this list).
    pub const ALL: [FaultPoint; POINTS] = [
        FaultPoint::KernelLaunch,
        FaultPoint::HierarchyBuild,
        FaultPoint::GraphLoad,
        FaultPoint::GraphStore,
        FaultPoint::JobPickup,
        FaultPoint::Solve,
        FaultPoint::WireRead,
        FaultPoint::WireWrite,
        FaultPoint::RouteDispatch,
        FaultPoint::NodeProbe,
        FaultPoint::DeviceLaunch,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FaultPoint::KernelLaunch => "kernel_launch",
            FaultPoint::HierarchyBuild => "hierarchy_build",
            FaultPoint::GraphLoad => "graph_load",
            FaultPoint::GraphStore => "graph_store",
            FaultPoint::JobPickup => "job_pickup",
            FaultPoint::Solve => "solve",
            FaultPoint::WireRead => "wire_read",
            FaultPoint::WireWrite => "wire_write",
            FaultPoint::RouteDispatch => "route_dispatch",
            FaultPoint::NodeProbe => "node_probe",
            FaultPoint::DeviceLaunch => "device_launch",
        }
    }

    pub fn from_name(name: &str) -> Option<FaultPoint> {
        FaultPoint::ALL.iter().copied().find(|p| p.name() == name)
    }

    fn index(self) -> usize {
        match self {
            FaultPoint::KernelLaunch => 0,
            FaultPoint::HierarchyBuild => 1,
            FaultPoint::GraphLoad => 2,
            FaultPoint::GraphStore => 3,
            FaultPoint::JobPickup => 4,
            FaultPoint::Solve => 5,
            FaultPoint::WireRead => 6,
            FaultPoint::WireWrite => 7,
            FaultPoint::RouteDispatch => 8,
            FaultPoint::NodeProbe => 9,
            FaultPoint::DeviceLaunch => 10,
        }
    }
}

/// The failure message injected at `point` (carries [`INJECTED_MARKER`]).
pub fn failure(point: FaultPoint) -> String {
    format!("{INJECTED_MARKER} at {}", point.name())
}

/// One armed point: fire with `prob` on a seeded deterministic stream.
struct Arm {
    prob: f64,
    seed: u64,
    /// Per-point check index — the position in this arm's decision
    /// stream. Monotonically increasing across checks.
    checks: AtomicU64,
}

impl Arm {
    fn decide(&self, point: FaultPoint) -> bool {
        // relaxed: the counter is a monotone ticket; each check claims a
        // unique stream index via the RMW itself, no other data is
        // published through it.
        let i = self.checks.fetch_add(1, Ordering::Relaxed);
        // One SplitMix64 draw keyed by (seed, point, index): bit-for-bit
        // reproducible for a fixed plane, independent across points.
        let mut x = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((point.index() as u64 + 1).wrapping_mul(0xA24BAED4963EE407))
            .wrapping_add(i);
        let draw = crate::rng::splitmix64(&mut x);
        let u = (draw >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < self.prob
    }
}

/// A set of armed injection points. Checks on unarmed points are free
/// (an array lookup); the engine and the hot layers consult a plane via
/// [`fire`] / [`FaultPlane::should_fire`].
pub struct FaultPlane {
    arms: [Option<Arm>; POINTS],
    /// Faults actually injected through this plane (not just checks).
    injected: AtomicU64,
}

impl Default for FaultPlane {
    fn default() -> Self {
        FaultPlane::disarmed()
    }
}

impl FaultPlane {
    /// A plane with no armed points — every check returns false.
    pub fn disarmed() -> FaultPlane {
        FaultPlane { arms: Default::default(), injected: AtomicU64::new(0) }
    }

    /// Arm `point` to fire with probability `prob` (clamped to `[0, 1]`)
    /// on the deterministic stream seeded by `seed`.
    pub fn arm(&mut self, point: FaultPoint, prob: f64, seed: u64) {
        self.arms[point.index()] = Some(Arm {
            prob: prob.clamp(0.0, 1.0),
            seed,
            checks: AtomicU64::new(0),
        });
    }

    /// Is any point armed? (Fast pre-check for hot paths.)
    pub fn armed_any(&self) -> bool {
        self.arms.iter().any(|a| a.is_some())
    }

    /// Is `point` armed?
    pub fn is_armed(&self, point: FaultPoint) -> bool {
        self.arms[point.index()].is_some()
    }

    /// Draw the next decision for `point`: true = inject a fault here.
    /// Unarmed points and suppressed threads (see [`suppress`]) never
    /// fire and do not advance the decision stream.
    pub fn should_fire(&self, point: FaultPoint) -> bool {
        let Some(arm) = &self.arms[point.index()] else {
            return false;
        };
        if suppressed() {
            return false;
        }
        let fire = arm.decide(point);
        if fire {
            // relaxed: monotone statistics counter, read approximately.
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// Faults injected through this plane so far.
    pub fn injected(&self) -> u64 {
        // relaxed: approximate statistics read.
        self.injected.load(Ordering::Relaxed)
    }

    /// Parse the `HEIPA_FAULTS` grammar:
    /// `point:prob[:seed][;point:prob[:seed]…]`, where `point` is a
    /// [`FaultPoint::name`] or `all`, `prob` is a float in `[0, 1]` and
    /// `seed` defaults to 1. Empty input yields a disarmed plane.
    ///
    /// ```
    /// let p = heipa::fault::FaultPlane::parse("solve:0.5:7;graph_load:1").unwrap();
    /// assert!(p.is_armed(heipa::fault::FaultPoint::Solve));
    /// assert!(!p.is_armed(heipa::fault::FaultPoint::WireRead));
    /// ```
    pub fn parse(spec: &str) -> Result<FaultPlane> {
        let mut plane = FaultPlane::disarmed();
        for part in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            let fields: Vec<&str> = part.split(':').collect();
            if !(2..=3).contains(&fields.len()) {
                bail!("fault spec `{part}` wants point:prob[:seed]");
            }
            let prob: f64 = fields[1]
                .parse()
                .map_err(|_| anyhow::anyhow!("bad fault probability `{}` in `{part}`", fields[1]))?;
            if !(0.0..=1.0).contains(&prob) {
                bail!("fault probability {prob} out of [0, 1] in `{part}`");
            }
            let seed: u64 = match fields.get(2) {
                Some(s) => s
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad fault seed `{s}` in `{part}`"))?,
                None => 1,
            };
            if fields[0] == "all" {
                for point in FaultPoint::ALL {
                    plane.arm(point, prob, seed);
                }
            } else {
                let point = FaultPoint::from_name(fields[0]).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown fault point `{}` (expected one of {}, or `all`)",
                        fields[0],
                        FaultPoint::ALL.map(FaultPoint::name).join(", ")
                    )
                })?;
                plane.arm(point, prob, seed);
            }
        }
        Ok(plane)
    }

    /// Build a per-job plane from `__fault.*` spec options:
    /// `__fault.<point> = <prob>` arms a point, `__fault.seed = <u64>`
    /// seeds the streams (default: 1). `attempt_salt` (the job's attempt
    /// number) is folded into every seed so retries of the same job draw
    /// fresh decisions. Returns `Ok(None)` when no `__fault.*` key is
    /// present; unknown points and malformed values are errors.
    pub fn from_options(
        options: &BTreeMap<String, String>,
        attempt_salt: u64,
    ) -> Result<Option<FaultPlane>> {
        let mut plane = FaultPlane::disarmed();
        let mut any = false;
        let seed: u64 = match options.get("__fault.seed") {
            Some(v) => {
                any = true;
                v.parse().map_err(|_| anyhow::anyhow!("bad __fault.seed `{v}`"))?
            }
            None => 1,
        };
        for (key, value) in options {
            let Some(name) = key.strip_prefix("__fault.") else {
                continue;
            };
            if name == "seed" {
                continue;
            }
            let point = FaultPoint::from_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown fault point `__fault.{name}`"))?;
            let prob: f64 = value
                .parse()
                .map_err(|_| anyhow::anyhow!("bad probability `{value}` for __fault.{name}"))?;
            if !(0.0..=1.0).contains(&prob) {
                bail!("probability {prob} for __fault.{name} out of [0, 1]");
            }
            // Salt the attempt number in so a retried attempt draws a
            // fresh (still deterministic) decision sequence.
            plane.arm(point, prob, seed ^ attempt_salt.wrapping_mul(0xD1B54A32D192ED03));
            any = true;
        }
        Ok(any.then_some(plane))
    }
}

/// The process-global plane, parsed once from `HEIPA_FAULTS` on first
/// use. An unset or empty variable yields a disarmed plane; a malformed
/// one panics on first access (loudly, at startup of whatever consults
/// it) rather than silently running without faults.
pub fn global() -> &'static FaultPlane {
    static GLOBAL: OnceLock<FaultPlane> = OnceLock::new();
    GLOBAL.get_or_init(|| match std::env::var("HEIPA_FAULTS") {
        Ok(spec) => FaultPlane::parse(&spec)
            .unwrap_or_else(|e| panic!("invalid HEIPA_FAULTS `{spec}`: {e:#}")),
        Err(_) => FaultPlane::disarmed(),
    })
}

thread_local! {
    /// Suppression depth for the current thread (see [`suppress`]).
    static SUPPRESS: Cell<u32> = const { Cell::new(0) };
}

/// Are fault checks suppressed on this thread?
pub fn suppressed() -> bool {
    SUPPRESS.with(|s| s.get() > 0)
}

/// Run `f` with every fault check on this thread suppressed. The engine
/// wraps its fallback chain in this so a degraded completion cannot be
/// re-faulted into oblivion by an always-on plane. Nests.
pub fn suppress<R>(f: impl FnOnce() -> R) -> R {
    struct Guard;
    impl Drop for Guard {
        fn drop(&mut self) {
            SUPPRESS.with(|s| s.set(s.get() - 1));
        }
    }
    SUPPRESS.with(|s| s.set(s.get() + 1));
    let _guard = Guard;
    f()
}

/// Check `point` against the per-job plane (if any), then the global
/// plane. True = inject a fault here. The short-circuit means a job-plane
/// hit does not advance the global stream (each plane owns its own
/// per-point decision sequence).
pub fn fire(plane: Option<&FaultPlane>, point: FaultPoint) -> bool {
    plane.is_some_and(|p| p.should_fire(point)) || global().should_fire(point)
}

/// Global-plane-only check — for layers that have no job context (the
/// device pool, graph IO, the wire loop).
#[inline]
pub fn fire_global(point: FaultPoint) -> bool {
    let g = global();
    g.is_armed(point) && g.should_fire(point)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_plane_never_fires() {
        let p = FaultPlane::disarmed();
        for point in FaultPoint::ALL {
            assert!(!p.should_fire(point));
        }
        assert_eq!(p.injected(), 0);
        assert!(!p.armed_any());
    }

    #[test]
    fn point_names_round_trip() {
        for point in FaultPoint::ALL {
            assert_eq!(FaultPoint::from_name(point.name()), Some(point));
        }
        assert_eq!(FaultPoint::from_name("nope"), None);
        assert!(failure(FaultPoint::Solve).contains(INJECTED_MARKER));
        assert!(failure(FaultPoint::Solve).contains("solve"));
    }

    #[test]
    fn probability_extremes() {
        let mut p = FaultPlane::disarmed();
        p.arm(FaultPoint::Solve, 1.0, 42);
        p.arm(FaultPoint::GraphLoad, 0.0, 42);
        for _ in 0..64 {
            assert!(p.should_fire(FaultPoint::Solve));
            assert!(!p.should_fire(FaultPoint::GraphLoad));
        }
        assert_eq!(p.injected(), 64);
    }

    #[test]
    fn decision_streams_are_deterministic_and_seeded() {
        let draw = |seed: u64| -> Vec<bool> {
            let mut p = FaultPlane::disarmed();
            p.arm(FaultPoint::Solve, 0.5, seed);
            (0..256).map(|_| p.should_fire(FaultPoint::Solve)).collect()
        };
        let a = draw(7);
        assert_eq!(a, draw(7), "same seed must reproduce the same sequence");
        assert_ne!(a, draw(8), "different seeds must diverge");
        let fires = a.iter().filter(|&&f| f).count();
        assert!((64..192).contains(&fires), "p=0.5 fired {fires}/256 times");
    }

    #[test]
    fn points_draw_independent_streams() {
        let mut p = FaultPlane::disarmed();
        p.arm(FaultPoint::Solve, 0.5, 3);
        p.arm(FaultPoint::JobPickup, 0.5, 3);
        let a: Vec<bool> = (0..128).map(|_| p.should_fire(FaultPoint::Solve)).collect();
        let b: Vec<bool> = (0..128).map(|_| p.should_fire(FaultPoint::JobPickup)).collect();
        assert_ne!(a, b, "same seed, different points must not share a stream");
    }

    #[test]
    fn parse_grammar() {
        let p = FaultPlane::parse("solve:0.5:7; graph_load:1").unwrap();
        assert!(p.is_armed(FaultPoint::Solve));
        assert!(p.is_armed(FaultPoint::GraphLoad));
        assert!(!p.is_armed(FaultPoint::WireRead));
        let all = FaultPlane::parse("all:0.25:9").unwrap();
        for point in FaultPoint::ALL {
            assert!(all.is_armed(point), "{}", point.name());
        }
        assert!(!FaultPlane::parse("").unwrap().armed_any());
        assert!(FaultPlane::parse("bogus:0.5").is_err());
        assert!(FaultPlane::parse("solve").is_err());
        assert!(FaultPlane::parse("solve:2.0").is_err());
        assert!(FaultPlane::parse("solve:0.5:x").is_err());
    }

    #[test]
    fn from_options_builds_salted_job_planes() {
        let mut opts = BTreeMap::new();
        assert!(FaultPlane::from_options(&opts, 1).unwrap().is_none());
        opts.insert("__fault.solve".into(), "0.5".into());
        opts.insert("__fault.seed".into(), "11".into());
        opts.insert("unrelated".into(), "1".into());
        let p1 = FaultPlane::from_options(&opts, 1).unwrap().unwrap();
        let p1b = FaultPlane::from_options(&opts, 1).unwrap().unwrap();
        let p2 = FaultPlane::from_options(&opts, 2).unwrap().unwrap();
        let seq = |p: &FaultPlane| -> Vec<bool> {
            (0..128).map(|_| p.should_fire(FaultPoint::Solve)).collect()
        };
        assert_eq!(seq(&p1), seq(&p1b), "same attempt must reproduce");
        assert_ne!(seq(&p1), seq(&p2), "attempts must draw fresh decisions");
        opts.insert("__fault.frob".into(), "0.5".into());
        assert!(FaultPlane::from_options(&opts, 1).is_err());
        opts.remove("__fault.frob");
        opts.insert("__fault.solve".into(), "nan?".into());
        assert!(FaultPlane::from_options(&opts, 1).is_err());
    }

    #[test]
    fn suppression_silences_checks_without_advancing_streams() {
        let mut p = FaultPlane::disarmed();
        p.arm(FaultPoint::Solve, 1.0, 1);
        assert!(p.should_fire(FaultPoint::Solve));
        suppress(|| {
            assert!(suppressed());
            assert!(!p.should_fire(FaultPoint::Solve));
            suppress(|| assert!(suppressed()));
            assert!(suppressed(), "nested suppression must not unwind early");
        });
        assert!(!suppressed());
        assert!(p.should_fire(FaultPoint::Solve));
        // Only the two unsuppressed checks were injected.
        assert_eq!(p.injected(), 2);
    }

    #[test]
    fn fire_prefers_the_job_plane() {
        let mut p = FaultPlane::disarmed();
        p.arm(FaultPoint::JobPickup, 1.0, 5);
        assert!(fire(Some(&p), FaultPoint::JobPickup));
        assert!(!fire(None, FaultPoint::JobPickup) || global().is_armed(FaultPoint::JobPickup));
    }
}
