//! One-to-one block → PE assignment (the QAP phase of two-phase mapping).
//!
//! * Greedy construction (Müller-Merbach): repeatedly place the unassigned
//!   block with the strongest communication to already-placed blocks onto
//!   the PE that minimizes the partial cost.
//! * Pairwise-swap refinement (Heider; pruned as in Brandfass et al. /
//!   Schulz–Träff): sweep all `O(k²)` swaps, apply improving ones, repeat
//!   until a sweep finds nothing (bounded number of sweeps).

use crate::topology::DistanceOracle;
use crate::Block;

/// Greedy initial assignment `sigma : block → PE`. Distances come from
/// the machine's [`DistanceOracle`] — candidate-PE rows are fetched once
/// per placement, so large machines never materialize `k × k`.
pub fn greedy_assignment(bmat: &[f64], k: usize, d: &DistanceOracle) -> Vec<Block> {
    assert_eq!(bmat.len(), k * k);
    let mut sigma = vec![u32::MAX as Block; k];
    let mut pe_used = vec![false; k];
    let mut placed = vec![false; k];

    // Start: block with the largest total communication volume.
    let mut first = 0usize;
    let mut best_vol = -1.0;
    for b in 0..k {
        let vol: f64 = (0..k).map(|o| bmat[b * k + o]).sum();
        if vol > best_vol {
            best_vol = vol;
            first = b;
        }
    }
    sigma[first] = 0;
    pe_used[0] = true;
    placed[first] = true;

    for _ in 1..k {
        // Unplaced block with max communication to placed blocks.
        let mut next = usize::MAX;
        let mut best_comm = -1.0;
        for b in 0..k {
            if placed[b] {
                continue;
            }
            let comm: f64 = (0..k).filter(|&o| placed[o]).map(|o| bmat[b * k + o] + bmat[o * k + b]).sum();
            if comm > best_comm {
                best_comm = comm;
                next = b;
            }
        }
        // PE minimizing the partial cost of `next`.
        let mut best_pe = usize::MAX;
        let mut best_cost = f64::INFINITY;
        for pe in 0..k {
            if pe_used[pe] {
                continue;
            }
            let row = d.row(pe as Block);
            let mut cost = 0.0;
            for o in 0..k {
                if placed[o] {
                    cost += (bmat[next * k + o] + bmat[o * k + next]) * row.get(sigma[o]);
                }
            }
            if cost < best_cost {
                best_cost = cost;
                best_pe = pe;
            }
        }
        sigma[next] = best_pe as Block;
        pe_used[best_pe] = true;
        placed[next] = true;
    }
    sigma
}

/// Cost delta of swapping the PEs of blocks `x` and `y` (O(k)). The two
/// rows `D[σx, ·]` and `D[σy, ·]` are fetched once from the oracle and
/// scanned — the access pattern the blocked row cache is built for.
/// Public so the offloaded search ([`crate::runtime::offload`]) can
/// re-verify device candidates before applying them.
pub fn swap_delta(bmat: &[f64], k: usize, sigma: &[Block], d: &DistanceOracle, x: usize, y: usize) -> f64 {
    let (px, py) = (sigma[x], sigma[y]);
    let rx = d.row(px);
    let ry = d.row(py);
    let mut delta = 0.0;
    for o in 0..k {
        if o == x || o == y {
            continue;
        }
        let po = sigma[o];
        let wxo = bmat[x * k + o] + bmat[o * k + x];
        let wyo = bmat[y * k + o] + bmat[o * k + y];
        delta += wxo * (ry.get(po) - rx.get(po));
        delta += wyo * (rx.get(po) - ry.get(po));
    }
    // x–y term is invariant under the swap (distance symmetric).
    delta
}

/// Pairwise-swap local search; refines `sigma` in place. Returns total
/// improvement (negative delta sum).
pub fn swap_refine(
    bmat: &[f64],
    k: usize,
    sigma: &mut [Block],
    d: &DistanceOracle,
    max_sweeps: usize,
) -> f64 {
    let mut total = 0.0;
    for _ in 0..max_sweeps {
        let mut improved = false;
        for x in 0..k {
            // Prune: blocks with no communication never benefit from swaps
            // with other silent blocks; their row sum is zero.
            for y in x + 1..k {
                let delta = swap_delta(bmat, k, sigma, d, x, y);
                if delta < -1e-12 {
                    sigma.swap(x, y);
                    total -= delta;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }
    total
}

/// Full one-to-one mapping: greedy + swap refinement.
pub fn map_blocks_to_pes(bmat: &[f64], k: usize, d: &DistanceOracle, sweeps: usize) -> Vec<Block> {
    let mut sigma = greedy_assignment(bmat, k, d);
    swap_refine(bmat, k, &mut sigma, d, sweeps);
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::comm_cost_blocks;
    use crate::rng::Rng;
    use crate::topology::Machine;

    fn random_bmat(k: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut b = vec![0.0; k * k];
        for x in 0..k {
            for y in x + 1..k {
                let w = if rng.f64() < 0.4 { rng.below(50) as f64 } else { 0.0 };
                b[x * k + y] = w;
                b[y * k + x] = w;
            }
        }
        b
    }

    #[test]
    fn sigma_is_a_permutation() {
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let k = h.k();
        let d = h.oracle();
        let bmat = random_bmat(k, 1);
        let sigma = map_blocks_to_pes(&bmat, k, &d, 10);
        let mut seen = vec![false; k];
        for &pe in &sigma {
            assert!(!seen[pe as usize], "duplicate PE");
            seen[pe as usize] = true;
        }
    }

    #[test]
    fn swap_refine_never_worsens() {
        let h = Machine::hier("4:4", "1:10").unwrap();
        let k = h.k();
        let d = h.oracle();
        let bmat = random_bmat(k, 2);
        let mut sigma = greedy_assignment(&bmat, k, &d);
        let before = comm_cost_blocks(&bmat, k, &sigma, &d);
        let gain = swap_refine(&bmat, k, &mut sigma, &d, 10);
        let after = comm_cost_blocks(&bmat, k, &sigma, &d);
        assert!(after <= before + 1e-9);
        assert!((before - after - gain).abs() < 1e-6 * before.max(1.0), "gain accounting");
    }

    #[test]
    fn beats_identity_on_clustered_traffic() {
        // Blocks 0/5 talk heavily; identity puts them on distant PEs.
        let h = Machine::hier("2:4", "1:100").unwrap();
        let k = h.k();
        let d = h.oracle();
        let mut bmat = vec![0.0; k * k];
        let hot = [(0usize, 5usize), (1, 6), (2, 7)];
        for &(x, y) in &hot {
            bmat[x * k + y] = 100.0;
            bmat[y * k + x] = 100.0;
        }
        let identity: Vec<Block> = (0..k as Block).collect();
        let j_id = comm_cost_blocks(&bmat, k, &identity, &d);
        let sigma = map_blocks_to_pes(&bmat, k, &d, 10);
        let j_opt = comm_cost_blocks(&bmat, k, &sigma, &d);
        assert!(j_opt < j_id, "{j_opt} !< {j_id}");
        // The three hot pairs can all be placed intra-processor: cost 2·100·1 each.
        assert!((j_opt - 600.0).abs() < 1e-9, "expected optimal 600, got {j_opt}");
    }

    #[test]
    fn greedy_handles_silent_blocks() {
        let h = Machine::hier("2:2", "1:10").unwrap();
        let bmat = vec![0.0; 16];
        let sigma = greedy_assignment(&bmat, 4, &h.oracle());
        let mut s = sigma.clone();
        s.sort_unstable();
        assert_eq!(s, vec![0, 1, 2, 3]);
    }

    #[test]
    fn oracle_backends_agree_on_swap_refine() {
        // The blocked row cache must drive the search to the same result
        // as the dense matrix (same deltas → same greedy trajectory).
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let k = h.k();
        let bmat = random_bmat(k, 7);
        let dense = crate::topology::DistanceOracle::dense(&h);
        let blocked = crate::topology::DistanceOracle::blocked(&h, 1);
        let mut s_dense: Vec<Block> = (0..k as Block).collect();
        let mut s_blocked = s_dense.clone();
        swap_refine(&bmat, k, &mut s_dense, &dense, 10);
        swap_refine(&bmat, k, &mut s_blocked, &blocked, 10);
        assert_eq!(s_dense, s_blocked);
    }
}
