//! The process-mapping algorithms: the paper's two GPU contributions and
//! every solver they are evaluated against.

pub mod gpu_hm;
pub mod gpu_im;
pub mod intmap;
pub mod jet;
pub mod qap;
pub mod sharedmap;

use crate::engine::{EngineCtx, MapOutcome, MapSpec};
use crate::graph::CsrGraph;
use crate::par::Pool;
use crate::topology::Machine;

/// Every algorithm in the paper's evaluation (§5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// GPU hierarchical multisection (Alg. 2 with Jet).
    GpuHm,
    /// GPU-HM with Jet's ultra refinement (18 iterations).
    GpuHmUltra,
    /// GPU integrated mapping (Alg. 3–6).
    GpuIm,
    /// SharedMap-like serial multisection, fast flavor.
    SharedMapF,
    /// SharedMap-like serial multisection, strong flavor.
    SharedMapS,
    /// IntMap-like serial integrated mapping, fast flavor.
    IntMapF,
    /// IntMap-like serial integrated mapping, strong flavor.
    IntMapS,
    /// Plain edge-cut Jet (§5.4: unfit for mapping by construction).
    Jet,
    /// Edge-cut Jet, ultra flavor.
    JetUltra,
}

impl Algorithm {
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::GpuHm => "gpu-hm",
            Algorithm::GpuHmUltra => "gpu-hm-ultra",
            Algorithm::GpuIm => "gpu-im",
            Algorithm::SharedMapF => "sharedmap-f",
            Algorithm::SharedMapS => "sharedmap-s",
            Algorithm::IntMapF => "intmap-f",
            Algorithm::IntMapS => "intmap-s",
            Algorithm::Jet => "jet",
            Algorithm::JetUltra => "jet-ultra",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "gpu-hm" => Algorithm::GpuHm,
            "gpu-hm-ultra" => Algorithm::GpuHmUltra,
            "gpu-im" => Algorithm::GpuIm,
            "sharedmap-f" => Algorithm::SharedMapF,
            "sharedmap-s" => Algorithm::SharedMapS,
            "intmap-f" => Algorithm::IntMapF,
            "intmap-s" => Algorithm::IntMapS,
            "jet" => Algorithm::Jet,
            "jet-ultra" => Algorithm::JetUltra,
            _ => return None,
        })
    }

    /// Device algorithms are costed with the GPU model; CPU baselines use
    /// host wall-clock.
    pub fn is_device(self) -> bool {
        matches!(
            self,
            Algorithm::GpuHm | Algorithm::GpuHmUltra | Algorithm::GpuIm | Algorithm::Jet | Algorithm::JetUltra
        )
    }

    /// All algorithms, in the paper's presentation order.
    pub fn all() -> [Algorithm; 9] {
        [
            Algorithm::GpuHm,
            Algorithm::GpuHmUltra,
            Algorithm::GpuIm,
            Algorithm::SharedMapF,
            Algorithm::SharedMapS,
            Algorithm::IntMapF,
            Algorithm::IntMapS,
            Algorithm::Jet,
            Algorithm::JetUltra,
        ]
    }
}

/// Run one algorithm end to end and measure it.
///
/// Thin shim over the engine's solver registry, kept for source
/// compatibility: no graph cache, no device runtime, no polish. New code
/// should build a [`MapSpec`] and call [`crate::engine::Engine::map`].
#[deprecated(note = "use engine::Engine::map with a MapSpec")]
pub fn run_algorithm(
    algo: Algorithm,
    pool: &Pool,
    g: &CsrGraph,
    m: &Machine,
    eps: f64,
    seed: u64,
) -> MapOutcome {
    let ctx = EngineCtx::host_only(pool.clone());
    // Solvers never touch spec.graph; the caller already resolved `g`.
    let spec = MapSpec::named("<caller-resolved>").eps(eps).seed(seed);
    crate::engine::solver(algo).solve(&ctx, g, m, &spec, &crate::cancel::CancelToken::new(), None)
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn names_roundtrip() {
        for a in Algorithm::all() {
            assert_eq!(Algorithm::from_name(a.name()), Some(a));
        }
        assert_eq!(Algorithm::from_name("nope"), None);
    }

    #[test]
    fn deprecated_shim_still_runs_every_algorithm() {
        let g = gen::grid2d(20, 20, false);
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let pool = Pool::new(1);
        for algo in Algorithm::all() {
            let r = run_algorithm(algo, &pool, &g, &h, 0.03, 1);
            crate::partition::validate_mapping(&r.mapping, g.n(), h.k())
                .unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
            assert_eq!(r.algorithm, algo);
            assert!(r.comm_cost > 0.0, "{}", algo.name());
            assert!(r.host_ms > 0.0);
            assert_eq!(r.phases.is_some(), algo.is_device());
        }
    }

    #[test]
    fn mapping_quality_order_holds_roughly() {
        // SharedMap-S should beat plain Jet (edge-cut) on J.
        let g = gen::stencil9(28, 28, 1);
        let h = Machine::hier("4:4:2", "1:10:100").unwrap();
        let pool = Pool::new(1);
        let sm = run_algorithm(Algorithm::SharedMapS, &pool, &g, &h, 0.03, 2);
        let jet = run_algorithm(Algorithm::Jet, &pool, &g, &h, 0.03, 2);
        assert!(sm.comm_cost < jet.comm_cost, "sharedmap {} !< jet {}", sm.comm_cost, jet.comm_cost);
    }
}
