//! Reimplementation of the Jet GPU graph partitioner (Gilbert et al.,
//! SISC 2024) — the partitioning engine inside GPU-HM and the edge-cut
//! comparison point of §5.4.
//!
//! Multilevel, via the unified [`crate::multilevel`] subsystem: the
//! configured coarsening scheme (preference matching + two-hop fallback,
//! or cluster LP) with CAS-hash contraction, CPU initial partitioning on
//! the ≤ 8·k coarsest graph (the paper delegates to METIS; we use the
//! kaffpa-lite substrate), then per-level Jet refinement (Alg. 4–6) with
//! the edge-cut objective and Jet's original negative-move filter.

use crate::graph::CsrGraph;
use crate::initial::{recursive_kway, MlConfig};
use crate::metrics::{Phase, PhaseBreakdown};
use crate::multilevel::{CoarsenConfig, CoarseHierarchy};
use crate::par::Pool;
use crate::partition::l_max;
use crate::refine::jet_loop::{jet_refine_with, JetConfig};
use crate::refine::jet_lp::Filter;
use crate::refine::{Objective, RefineWorkspace};
use crate::Block;

/// Jet partitioner configuration.
#[derive(Clone, Debug)]
pub struct JetPartConfig {
    /// Refinement iteration limit (12; 18 = ultra).
    pub iter_limit: usize,
    /// Negative-move filter constant `c`.
    pub c_factor: f64,
    /// Coarsening stage (scheme, rounds, level cap, salt) — shared with
    /// every other multilevel pipeline.
    pub coarsen: CoarsenConfig,
    /// Cooperative cancellation, polled at every coarsening-level
    /// boundary (and inside each Jet refinement round via [`JetConfig`]).
    pub cancel: crate::cancel::CancelToken,
}

impl Default for JetPartConfig {
    fn default() -> Self {
        JetPartConfig {
            iter_limit: 12,
            c_factor: 0.25,
            coarsen: CoarsenConfig::device(),
            cancel: crate::cancel::CancelToken::default(),
        }
    }
}

impl JetPartConfig {
    pub fn ultra() -> Self {
        JetPartConfig { iter_limit: 18, ..Default::default() }
    }
}

/// Partition `g` into `k` ε-balanced blocks minimizing edge-cut.
/// `phases` (optional) accumulates the per-phase breakdown.
pub fn jet_partition(
    pool: &Pool,
    g: &CsrGraph,
    k: usize,
    eps: f64,
    seed: u64,
    cfg: &JetPartConfig,
    phases: Option<&mut PhaseBreakdown>,
) -> Vec<Block> {
    jet_partition_with(pool, g, k, eps, seed, cfg, phases, None)
}

/// [`jet_partition`] over an optional prebuilt hierarchy (the engine's
/// hierarchy cache). `prebuilt` must have been built for this graph with
/// `cfg.coarsen` and this `(k, eps)`; when `None`, the hierarchy is
/// built here (and its build phases land in `phases`).
#[allow(clippy::too_many_arguments)]
pub fn jet_partition_with(
    pool: &Pool,
    g: &CsrGraph,
    k: usize,
    eps: f64,
    seed: u64,
    cfg: &JetPartConfig,
    mut phases: Option<&mut PhaseBreakdown>,
    prebuilt: Option<&CoarseHierarchy>,
) -> Vec<Block> {
    let total = g.total_vweight();
    let lmax = l_max(total, k, eps);

    let mut owned = None;
    let Some(hier) = CoarseHierarchy::resolve(
        prebuilt,
        &mut owned,
        pool,
        g,
        k,
        lmax,
        &cfg.coarsen,
        &cfg.cancel,
        phases.as_deref_mut(),
    ) else {
        // Cancelled mid-coarsening: the engine discards the result, so
        // any structurally valid assignment will do.
        return vec![0 as Block; g.n()];
    };

    // Initial partitioning on the CPU.
    let part = {
        let run = || recursive_kway(hier.coarsest(), k, eps, seed ^ 0x1111, &MlConfig::fast());
        match phases.as_deref_mut() {
            Some(p) => p.time_cpu(Phase::InitialPartitioning, run),
            None => run(),
        }
    };

    let jet_cfg = JetConfig {
        iter_limit: cfg.iter_limit,
        filter: Filter::JetNegative { c_factor: cfg.c_factor },
        seed,
        cancel: cfg.cancel.clone(),
        ..Default::default()
    };
    // One workspace reused across every level of the uncoarsening chain.
    let mut ws = RefineWorkspace::with_capacity(g.n(), k);
    // Uncoarsening: project + refine per level. A cancelled run still
    // projects to the finest level (the mapping must stay structurally
    // valid) but skips the per-level refinement.
    let part = hier.uncoarsen(pool, part, phases.as_deref_mut(), |_lev, gl, el, p| {
        if !cfg.cancel.is_cancelled() {
            jet_refine_with(pool, gl, el, p, k, lmax, &Objective::Cut, &jet_cfg, &mut ws);
        }
    });
    // Modeled D2H download of the final partition.
    match phases.as_deref_mut() {
        Some(p) => p.time(Phase::Misc, || crate::par::ledger::charge(1, part.len() as u64)),
        None => crate::par::ledger::charge(1, part.len() as u64),
    }
    part
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::multilevel::BuildParams;
    use std::sync::Arc;
    use crate::partition::{edge_cut, is_balanced};

    #[test]
    fn partitions_grid_balanced_low_cut() {
        let g = gen::grid2d(40, 40, false);
        let pool = Pool::new(1);
        let part = jet_partition(&pool, &g, 4, 0.03, 1, &JetPartConfig::default(), None);
        assert!(is_balanced(&g, &part, 4, 0.031));
        let cut = edge_cut(&g, &part);
        // 40×40 grid, k=4: good cuts are ≈ 80; accept < 160.
        assert!(cut < 160.0, "cut {cut}");
    }

    #[test]
    fn quality_comparable_to_serial_substrate() {
        let g = gen::rgg(4_000, 0.045, 2);
        let pool = Pool::new(1);
        let jet = jet_partition(&pool, &g, 8, 0.03, 3, &JetPartConfig::default(), None);
        let serial = recursive_kway(&g, 8, 0.03, 3, &MlConfig::default());
        let (cj, cs) = (edge_cut(&g, &jet), edge_cut(&g, &serial));
        assert!(is_balanced(&g, &jet, 8, 0.031));
        assert!(cj <= cs * 1.3, "jet {cj} vs serial {cs}");
    }

    #[test]
    fn ultra_not_worse() {
        let g = gen::delaunay_like(50, 5);
        let pool = Pool::new(1);
        let d = edge_cut(&g, &jet_partition(&pool, &g, 8, 0.03, 7, &JetPartConfig::default(), None));
        let u = edge_cut(&g, &jet_partition(&pool, &g, 8, 0.03, 7, &JetPartConfig::ultra(), None));
        assert!(u <= d * 1.10, "ultra {u} vs default {d}");
    }

    #[test]
    fn phase_breakdown_covers_pipeline() {
        let g = gen::grid2d(50, 50, false);
        let pool = Pool::new(1);
        let mut phases = PhaseBreakdown::default();
        let _ = jet_partition(&pool, &g, 4, 0.03, 1, &JetPartConfig::default(), Some(&mut phases));
        assert!(phases.device_ms(Phase::Coarsening) > 0.0);
        assert!(phases.device_ms(Phase::Contraction) > 0.0);
        assert!(phases.device_ms(Phase::InitialPartitioning) > 0.0);
        assert!(phases.device_ms(Phase::RefineRebalance) > 0.0);
        assert!(!phases.matched_fractions().is_empty(), "matched fractions recorded per level");
    }

    #[test]
    fn small_graph_no_coarsening_needed() {
        let g = gen::grid2d(6, 6, false);
        let pool = Pool::new(1);
        let part = jet_partition(&pool, &g, 2, 0.10, 1, &JetPartConfig::default(), None);
        assert!(is_balanced(&g, &part, 2, 0.11));
    }

    #[test]
    fn prebuilt_hierarchy_is_bit_identical_to_inline_build() {
        let g = gen::rgg(2_500, 0.05, 6);
        let pool = Pool::new(1);
        let cfg = JetPartConfig::default();
        let params = BuildParams {
            coarsest: cfg.coarsen.coarsest_for(8),
            lmax: l_max(g.total_vweight(), 8, 0.03),
            seed: cfg.coarsen.salt,
        };
        let hier = CoarseHierarchy::build(
            &pool,
            Arc::new(g.clone()),
            &params,
            &cfg.coarsen,
            &crate::cancel::CancelToken::new(),
            None,
        )
        .unwrap();
        let fresh = jet_partition(&pool, &g, 8, 0.03, 5, &cfg, None);
        let reused = jet_partition_with(&pool, &g, 8, 0.03, 5, &cfg, None, Some(&hier));
        assert_eq!(fresh, reused, "cached-hierarchy path must be bit-identical");
    }
}
