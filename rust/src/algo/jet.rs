//! Reimplementation of the Jet GPU graph partitioner (Gilbert et al.,
//! SISC 2024) — the partitioning engine inside GPU-HM and the edge-cut
//! comparison point of §5.4.
//!
//! Multilevel: device preference matching (+ two-hop when < 75 % matched),
//! CAS-hash contraction (Alg. 3), CPU initial partitioning on the ≤ 8·k
//! coarsest graph (the paper delegates to METIS; we use the kaffpa-lite
//! substrate), then per-level Jet refinement (Alg. 4–6) with the edge-cut
//! objective and Jet's original negative-move filter.

use crate::coarsen::{match_par::preference_matching, matched_fraction, matching_to_map, twohop::twohop_matching};
use crate::coarsen::contract_cas::contract_cas;
use crate::graph::{CsrGraph, EdgeList};
use crate::initial::{recursive_kway, MlConfig};
use crate::metrics::{Phase, PhaseBreakdown};
use crate::par::Pool;
use crate::partition::l_max;
use crate::refine::jet_loop::{jet_refine_with, JetConfig};
use crate::refine::jet_lp::Filter;
use crate::refine::{Objective, RefineWorkspace};
use crate::{Block, Vertex};

/// Jet partitioner configuration.
#[derive(Clone, Debug)]
pub struct JetPartConfig {
    /// Refinement iteration limit (12; 18 = ultra).
    pub iter_limit: usize,
    /// Negative-move filter constant `c`.
    pub c_factor: f64,
    /// Coarsen until `coarsest_factor · k` vertices (paper: 8).
    pub coarsest_factor: usize,
    /// Matching rounds per level.
    pub match_rounds: usize,
    /// Cooperative cancellation, polled at every coarsening-level
    /// boundary (and inside each Jet refinement round via [`JetConfig`]).
    pub cancel: crate::cancel::CancelToken,
}

impl Default for JetPartConfig {
    fn default() -> Self {
        JetPartConfig {
            iter_limit: 12,
            c_factor: 0.25,
            coarsest_factor: 8,
            match_rounds: 8,
            cancel: crate::cancel::CancelToken::default(),
        }
    }
}

impl JetPartConfig {
    pub fn ultra() -> Self {
        JetPartConfig { iter_limit: 18, ..Default::default() }
    }
}

/// Partition `g` into `k` ε-balanced blocks minimizing edge-cut.
/// `phases` (optional) accumulates the per-phase breakdown.
pub fn jet_partition(
    pool: &Pool,
    g: &CsrGraph,
    k: usize,
    eps: f64,
    seed: u64,
    cfg: &JetPartConfig,
    mut phases: Option<&mut PhaseBreakdown>,
) -> Vec<Block> {
    let total = g.total_vweight();
    let lmax = l_max(total, k, eps);
    let coarsest = (cfg.coarsest_factor * k).max(64);

    macro_rules! timed {
        ($ph:expr, $e:expr) => {{
            match phases.as_deref_mut() {
                Some(p) => p.time($ph, || $e),
                None => $e,
            }
        }};
    }
    macro_rules! timed_cpu {
        ($ph:expr, $e:expr) => {{
            match phases.as_deref_mut() {
                Some(p) => p.time_cpu($ph, || $e),
                None => $e,
            }
        }};
    }

    // Coarsening.
    let mut graphs: Vec<CsrGraph> = vec![];
    let mut edge_lists: Vec<EdgeList> = vec![];
    let mut maps: Vec<Vec<Vertex>> = vec![];
    let mut cur = g.clone();
    let mut cur_el = timed!(Phase::Misc, {
        // Modeled H2D upload of the CSR graph (xadj + adj + weights).
        crate::par::ledger::charge(3, (cur.n() + 2 * cur.num_directed()) as u64);
        EdgeList::build_par(pool, &cur)
    });
    let mut level = 0u64;
    while cur.n() > coarsest {
        // Coarsening-level cancellation boundary: the result is discarded
        // by the engine, so any structurally valid assignment will do.
        if cfg.cancel.is_cancelled() {
            return vec![0 as Block; g.n()];
        }
        let mut mate = timed!(
            Phase::Coarsening,
            preference_matching(&cur, pool, lmax, seed ^ (level << 32), cfg.match_rounds)
        );
        if matched_fraction(&mate) < 0.75 {
            timed_cpu!(Phase::Coarsening, {
                twohop_matching(&cur, &mut mate, lmax);
            });
        }
        let (map, nc) = matching_to_map(&mate);
        if nc as f64 > cur.n() as f64 * 0.96 {
            break; // stalled
        }
        let coarse = timed!(Phase::Contraction, contract_cas(pool, &cur, &cur_el, &map, nc));
        let coarse_el = timed!(Phase::Misc, EdgeList::build_par(pool, &coarse));
        graphs.push(cur);
        edge_lists.push(cur_el);
        maps.push(map);
        cur = coarse;
        cur_el = coarse_el;
        level += 1;
    }

    // Initial partitioning on the CPU.
    let mut part = timed_cpu!(
        Phase::InitialPartitioning,
        recursive_kway(&cur, k, eps, seed ^ 0x1111, &MlConfig::fast())
    );

    // Refine the coarsest level too.
    let jet_cfg = JetConfig {
        iter_limit: cfg.iter_limit,
        filter: Filter::JetNegative { c_factor: cfg.c_factor },
        seed,
        cancel: cfg.cancel.clone(),
        ..Default::default()
    };
    // One workspace reused across every level of the uncoarsening chain.
    let mut ws = RefineWorkspace::with_capacity(g.n(), k);
    if !cfg.cancel.is_cancelled() {
        timed!(Phase::RefineRebalance, {
            jet_refine_with(
                pool, &cur, &cur_el, &mut part, k, lmax, &Objective::Cut, &jet_cfg, &mut ws,
            )
        });
    }

    // Uncoarsening. A cancelled run still projects down to the finest
    // level (the mapping must stay structurally valid) but skips the
    // per-level refinement.
    for lev in (0..maps.len()).rev() {
        let fine = &graphs[lev];
        let el = &edge_lists[lev];
        let map = &maps[lev];
        let mut fine_part = vec![0 as Block; fine.n()];
        timed!(Phase::Uncontraction, {
            let fp = crate::par::SharedMut::new(&mut fine_part);
            pool.parallel_for(fine.n(), |v| unsafe {
                fp.write(v, part[map[v] as usize]);
            });
        });
        if !cfg.cancel.is_cancelled() {
            timed!(Phase::RefineRebalance, {
                jet_refine_with(
                    pool, fine, el, &mut fine_part, k, lmax, &Objective::Cut, &jet_cfg, &mut ws,
                )
            });
        }
        part = fine_part;
    }
    // Modeled D2H download of the final partition.
    timed!(Phase::Misc, crate::par::ledger::charge(1, part.len() as u64));
    part
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{edge_cut, is_balanced};

    #[test]
    fn partitions_grid_balanced_low_cut() {
        let g = gen::grid2d(40, 40, false);
        let pool = Pool::new(1);
        let part = jet_partition(&pool, &g, 4, 0.03, 1, &JetPartConfig::default(), None);
        assert!(is_balanced(&g, &part, 4, 0.031));
        let cut = edge_cut(&g, &part);
        // 40×40 grid, k=4: good cuts are ≈ 80; accept < 160.
        assert!(cut < 160.0, "cut {cut}");
    }

    #[test]
    fn quality_comparable_to_serial_substrate() {
        let g = gen::rgg(4_000, 0.045, 2);
        let pool = Pool::new(1);
        let jet = jet_partition(&pool, &g, 8, 0.03, 3, &JetPartConfig::default(), None);
        let serial = recursive_kway(&g, 8, 0.03, 3, &MlConfig::default());
        let (cj, cs) = (edge_cut(&g, &jet), edge_cut(&g, &serial));
        assert!(is_balanced(&g, &jet, 8, 0.031));
        assert!(cj <= cs * 1.3, "jet {cj} vs serial {cs}");
    }

    #[test]
    fn ultra_not_worse() {
        let g = gen::delaunay_like(50, 5);
        let pool = Pool::new(1);
        let d = edge_cut(&g, &jet_partition(&pool, &g, 8, 0.03, 7, &JetPartConfig::default(), None));
        let u = edge_cut(&g, &jet_partition(&pool, &g, 8, 0.03, 7, &JetPartConfig::ultra(), None));
        assert!(u <= d * 1.10, "ultra {u} vs default {d}");
    }

    #[test]
    fn phase_breakdown_covers_pipeline() {
        let g = gen::grid2d(50, 50, false);
        let pool = Pool::new(1);
        let mut phases = PhaseBreakdown::default();
        let _ = jet_partition(&pool, &g, 4, 0.03, 1, &JetPartConfig::default(), Some(&mut phases));
        assert!(phases.device_ms(Phase::Coarsening) > 0.0);
        assert!(phases.device_ms(Phase::Contraction) > 0.0);
        assert!(phases.device_ms(Phase::InitialPartitioning) > 0.0);
        assert!(phases.device_ms(Phase::RefineRebalance) > 0.0);
    }

    #[test]
    fn small_graph_no_coarsening_needed() {
        let g = gen::grid2d(6, 6, false);
        let pool = Pool::new(1);
        let part = jet_partition(&pool, &g, 2, 0.10, 1, &JetPartConfig::default(), None);
        assert!(is_balanced(&g, &part, 2, 0.11));
    }
}
