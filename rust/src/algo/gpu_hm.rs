//! GPU-HM — hierarchical multisection on the device (paper §4.1,
//! Algorithms 1 + 2).
//!
//! Recursively partitions the task graph along the machine model's
//! section schedule with the Jet partitioner ([`super::jet`]), computing
//! the adaptive imbalance ε′ (Eq. 2) for every call and building the
//! induced subgraphs entirely with device kernels (Alg. 1,
//! [`crate::graph::subgraph`]). The PE ids of the final mapping fall out
//! of the recursion structure. Irregular models (flat schedule `[k]`)
//! degenerate to a single k-way partition.

use super::jet::{jet_partition, JetPartConfig};
use crate::graph::subgraph::build_all_subgraphs;
use crate::graph::CsrGraph;
use crate::metrics::{Phase, PhaseBreakdown};
use crate::par::Pool;
use crate::topology::{Hierarchy, Machine};
use crate::{Block, Vertex};

/// GPU-HM configuration: the Jet flavor used for every multisection step.
#[derive(Clone, Debug)]
pub struct GpuHmConfig {
    pub jet: JetPartConfig,
    /// Use the adaptive imbalance ε′ of Eq. 2 (ablation A1 disables it).
    pub adaptive: bool,
    /// Cooperative cancellation, polled before every multisection node
    /// (callers should also set `jet.cancel` so the inner partitioner
    /// stops at its own coarsening/round boundaries).
    pub cancel: crate::cancel::CancelToken,
}

impl GpuHmConfig {
    /// Default flavor (Jet with 12 refinement iterations).
    pub fn default_flavor() -> Self {
        GpuHmConfig {
            jet: JetPartConfig::default(),
            adaptive: true,
            cancel: crate::cancel::CancelToken::default(),
        }
    }

    /// The *ultra* flavor (18 iterations; paper's GPU-HM-ultra).
    pub fn ultra() -> Self {
        GpuHmConfig {
            jet: JetPartConfig::ultra(),
            adaptive: true,
            cancel: crate::cancel::CancelToken::default(),
        }
    }
}

/// Run GPU-HM. Returns the vertex → PE mapping; `phases` accumulates the
/// partitioning / subgraph-construction split (the paper reports > 95 %
/// of the runtime in partitioning).
pub fn gpu_hm(
    pool: &Pool,
    g: &CsrGraph,
    m: &Machine,
    eps: f64,
    seed: u64,
    cfg: &GpuHmConfig,
    mut phases: Option<&mut PhaseBreakdown>,
) -> Vec<Block> {
    let k = m.k();
    let total = g.total_vweight();
    let sched = m.schedule();
    let ell = sched.len();
    let mut mapping = vec![0 as Block; g.n()];

    // Explicit recursion stack: (subgraph, original ids, level, PE offset).
    let mut stack: Vec<(CsrGraph, Vec<Vertex>, usize, Block)> =
        vec![(g.clone(), (0..g.n() as Vertex).collect(), ell, 0)];

    while let Some((sub, orig, level, pe_off)) = stack.pop() {
        // Multisection-node cancellation boundary (every node runs one
        // full partition call, i.e. at least one coarsening level).
        if cfg.cancel.is_cancelled() {
            return mapping;
        }
        if sub.n() == 0 {
            continue;
        }
        let a_i = sched[level - 1] as usize;
        let k_sub: usize = sched[..level].iter().map(|&x| x as usize).product();
        // Line 2: adaptive imbalance (Eq. 2).
        let eps_prime = if cfg.adaptive {
            Hierarchy::adaptive_imbalance(eps, total, sub.total_vweight().max(1), k, k_sub, level)
                .max(0.001)
        } else {
            eps
        };
        // Line 3: GPU graph partitioner.
        let part = jet_partition(
            pool,
            &sub,
            a_i,
            eps_prime,
            seed ^ (pe_off as u64) << 20 ^ (level as u64),
            &cfg.jet,
            phases.as_deref_mut(),
        );
        if level == 1 {
            // Lines 4–6: propagate Π′ into the final mapping.
            for (i, &v) in orig.iter().enumerate() {
                mapping[v as usize] = pe_off + part[i];
            }
        } else {
            // Lines 7–8: build subgraphs on the device and recurse.
            let span = m.pes_per_block_at_level(level) as Block;
            let subs = match phases.as_deref_mut() {
                Some(p) => p.time(Phase::Misc, || build_all_subgraphs(pool, &sub, &part, a_i)),
                None => build_all_subgraphs(pool, &sub, &part, a_i),
            };
            for (b, s) in subs.into_iter().enumerate() {
                let sub_orig: Vec<Vertex> =
                    s.local_to_parent.iter().map(|&lv| orig[lv as usize]).collect();
                stack.push((s.graph, sub_orig, level - 1, pe_off + b as Block * span));
            }
        }
    }
    mapping
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{comm_cost, is_balanced, validate_mapping};

    #[test]
    fn balanced_valid_mapping_paper_hierarchy() {
        let g = gen::grid2d(32, 32, false);
        let h = Machine::hier("4:8:2", "1:10:100").unwrap();
        let pool = Pool::new(1);
        let m = gpu_hm(&pool, &g, &h, 0.03, 1, &GpuHmConfig::default_flavor(), None);
        validate_mapping(&m, g.n(), h.k()).unwrap();
        assert!(
            is_balanced(&g, &m, h.k(), 0.04),
            "imbalance {}",
            crate::partition::imbalance(&g, &m, h.k())
        );
    }

    #[test]
    fn competitive_with_serial_sharedmap() {
        let g = gen::stencil9(35, 35, 2);
        let h = Machine::hier("4:4", "1:10").unwrap();
        let pool = Pool::new(1);
        let m_gpu = gpu_hm(&pool, &g, &h, 0.03, 3, &GpuHmConfig::ultra(), None);
        let m_cpu = super::super::sharedmap::sharedmap(
            &g, &h, 0.03, 3, &super::super::sharedmap::SharedMapConfig::fast(),
        );
        let (jg, jc) = (comm_cost(&g, &m_gpu, &h), comm_cost(&g, &m_cpu, &h));
        // Paper: GPU-HM within ~12% of SharedMap; allow slack on tiny instances.
        assert!(jg <= jc * 1.35, "gpu-hm {jg} vs sharedmap {jc}");
    }

    #[test]
    fn ultra_not_worse_than_default() {
        let g = gen::delaunay_like(45, 4);
        let h = Machine::hier("4:8", "1:10").unwrap();
        let pool = Pool::new(1);
        let jd = comm_cost(&g, &gpu_hm(&pool, &g, &h, 0.03, 5, &GpuHmConfig::default_flavor(), None), &h);
        let ju = comm_cost(&g, &gpu_hm(&pool, &g, &h, 0.03, 5, &GpuHmConfig::ultra(), None), &h);
        assert!(ju <= jd * 1.10, "ultra {ju} vs default {jd}");
    }

    #[test]
    fn partitioning_dominates_runtime() {
        // Paper: subgraph construction < 5% of GPU-HM runtime.
        let g = gen::rgg(6_000, 0.04, 6);
        let h = Machine::hier("4:8:2", "1:10:100").unwrap();
        let pool = Pool::new(1);
        let mut phases = PhaseBreakdown::default();
        let _ = gpu_hm(&pool, &g, &h, 0.03, 1, &GpuHmConfig::default_flavor(), Some(&mut phases));
        let misc_share = phases.share(Phase::Misc);
        assert!(misc_share < 25.0, "subgraph/misc share {misc_share}%");
    }
}
