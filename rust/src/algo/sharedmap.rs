//! SharedMap-like serial hierarchical multisection (Schulz & Woydt 2025) —
//! the state-of-the-art CPU baseline of the paper's evaluation.
//!
//! Recursively partitions the task graph along the machine model's
//! section schedule (islands → racks → … → PEs) with the **adaptive
//! imbalance** ε′ of
//! Eq. 2, which guarantees the final k-way mapping is ε-balanced. The
//! Fast/Strong configurations trade multilevel effort (tries, FM passes)
//! and final refinement for speed, mirroring SharedMap's `-F`/`-S`.

use crate::graph::subgraph::build_all_subgraphs_serial;
use crate::graph::CsrGraph;
use crate::initial::{recursive_kway, MlConfig};
use crate::refine::{lp_serial::lp_refine_serial, Objective};
use crate::topology::{Hierarchy, Machine};
use crate::{Block, Vertex};

/// Configuration for the serial multisection solver.
#[derive(Clone, Debug)]
pub struct SharedMapConfig {
    pub ml: MlConfig,
    /// Serial LP (J-objective) rounds on the final mapping.
    pub final_refine_rounds: usize,
    /// Use the adaptive imbalance ε′ of Eq. 2 (ablation A1 disables it
    /// and partitions every level with the raw ε).
    pub adaptive: bool,
    /// Cooperative cancellation, polled before every multisection node.
    pub cancel: crate::cancel::CancelToken,
}

impl SharedMapConfig {
    pub fn fast() -> Self {
        SharedMapConfig {
            ml: MlConfig::fast(),
            final_refine_rounds: 0,
            adaptive: true,
            cancel: crate::cancel::CancelToken::default(),
        }
    }

    pub fn strong() -> Self {
        SharedMapConfig {
            ml: MlConfig::strong(),
            final_refine_rounds: 12,
            adaptive: true,
            cancel: crate::cancel::CancelToken::default(),
        }
    }
}

/// Serial hierarchical multisection with adaptive imbalance.
/// Returns the vertex → PE mapping.
pub fn sharedmap(g: &CsrGraph, m: &Machine, eps: f64, seed: u64, cfg: &SharedMapConfig) -> Vec<Block> {
    let k = m.k();
    let total = g.total_vweight();
    let mut mapping = vec![0 as Block; g.n()];
    // Work stack: (subgraph, original vertex ids, level, PE offset).
    let sched = m.schedule();
    let ell = sched.len();
    let mut stack: Vec<(CsrGraph, Vec<Vertex>, usize, Block)> = vec![(
        g.clone(),
        (0..g.n() as Vertex).collect(),
        ell,
        0,
    )];

    while let Some((sub, orig, level, pe_off)) = stack.pop() {
        // Multisection-node cancellation boundary: the zero-initialized
        // remainder of `mapping` is structurally valid; the engine
        // discards cancelled results anyway.
        if cfg.cancel.is_cancelled() {
            return mapping;
        }
        if sub.n() == 0 {
            continue;
        }
        let a_i = sched[level - 1] as usize;
        let k_sub: usize = sched[..level].iter().map(|&x| x as usize).product();
        let eps_prime = if cfg.adaptive {
            Hierarchy::adaptive_imbalance(eps, total, sub.total_vweight().max(1), k, k_sub, level)
                .max(0.001)
        } else {
            eps
        };
        let part = recursive_kway(&sub, a_i, eps_prime, seed ^ (pe_off as u64) << 20, &cfg.ml);
        if level == 1 {
            // Innermost: blocks are PEs.
            for (i, &v) in orig.iter().enumerate() {
                mapping[v as usize] = pe_off + part[i];
            }
        } else {
            let span = m.pes_per_block_at_level(level) as Block;
            let subs = build_all_subgraphs_serial(&sub, &part, a_i);
            for (b, s) in subs.into_iter().enumerate() {
                let sub_orig: Vec<Vertex> =
                    s.local_to_parent.iter().map(|&lv| orig[lv as usize]).collect();
                stack.push((s.graph, sub_orig, level - 1, pe_off + b as Block * span));
            }
        }
    }

    // Final mapping-aware refinement (Strong flavor).
    if cfg.final_refine_rounds > 0 && !cfg.cancel.is_cancelled() {
        let lmax = crate::partition::l_max(total, k, eps);
        lp_refine_serial(
            g,
            &mut mapping,
            k,
            lmax,
            &Objective::Comm(m),
            cfg.final_refine_rounds,
            seed ^ 0xfeed,
        );
    }
    mapping
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{comm_cost, is_balanced, validate_mapping};

    #[test]
    fn produces_balanced_mapping() {
        let g = gen::grid2d(24, 24, false);
        let h = Machine::hier("4:8:2", "1:10:100").unwrap();
        let m = sharedmap(&g, &h, 0.03, 1, &SharedMapConfig::fast());
        validate_mapping(&m, g.n(), h.k()).unwrap();
        assert!(is_balanced(&g, &m, h.k(), 0.035), "imbalance {}", crate::partition::imbalance(&g, &m, h.k()));
    }

    #[test]
    fn beats_random_mapping_substantially() {
        let g = gen::stencil9(30, 30, 3);
        let h = Machine::hier("4:4", "1:10").unwrap();
        let m = sharedmap(&g, &h, 0.03, 2, &SharedMapConfig::fast());
        let mut rng = crate::rng::Rng::new(3);
        let random: Vec<Block> = (0..g.n()).map(|_| rng.below(h.k() as u64) as Block).collect();
        let j_m = comm_cost(&g, &m, &h);
        let j_r = comm_cost(&g, &random, &h);
        assert!(j_m < j_r * 0.5, "multisection {j_m} vs random {j_r}");
    }

    #[test]
    fn strong_at_least_as_good_as_fast() {
        let g = gen::grid2d(20, 20, false);
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let jf = comm_cost(&g, &sharedmap(&g, &h, 0.03, 5, &SharedMapConfig::fast()), &h);
        let js = comm_cost(&g, &sharedmap(&g, &h, 0.03, 5, &SharedMapConfig::strong()), &h);
        assert!(js <= jf * 1.10, "strong {js} much worse than fast {jf}");
    }

    #[test]
    fn single_level_hierarchy_is_plain_partitioning() {
        let g = gen::grid2d(12, 12, false);
        let h = Machine::hier("4", "1").unwrap();
        let m = sharedmap(&g, &h, 0.05, 7, &SharedMapConfig::fast());
        validate_mapping(&m, g.n(), 4).unwrap();
        assert!(is_balanced(&g, &m, 4, 0.06));
    }

    #[test]
    fn all_pes_used_on_big_enough_graph() {
        let g = gen::rgg(4_000, 0.04, 9);
        let h = Machine::hier("4:8", "1:10").unwrap();
        let m = sharedmap(&g, &h, 0.03, 4, &SharedMapConfig::fast());
        let mut used = vec![false; h.k()];
        for &pe in &m {
            used[pe as usize] = true;
        }
        assert!(used.iter().all(|&u| u), "some PE unused");
    }
}
