//! GPU-IM — integrated mapping inside the multilevel pipeline
//! (paper §4.2; the paper's fastest algorithm).
//!
//! Built on the unified [`crate::multilevel`] subsystem: the configured
//! coarsening scheme (preference matching with the `expansion*²` rating
//! + two-hop fallback, or size-constrained cluster LP) with CAS-hash
//! contraction (Alg. 3), CPU hierarchical-multisection initial mapping
//! on the ≤ 8·k coarsest graph, parallel uncontraction, and the
//! Jet-adapted refinement driven by the mapping gain Eq. 1 (Alg. 4–6)
//! with the non-negative first filter.

use super::sharedmap::{sharedmap, SharedMapConfig};
use crate::graph::CsrGraph;
use crate::metrics::{Phase, PhaseBreakdown};
use crate::multilevel::{CoarsenConfig, CoarseHierarchy};
use crate::par::Pool;
use crate::partition::l_max;
use crate::refine::jet_loop::{jet_refine_with, JetConfig};
use crate::refine::jet_lp::Filter;
use crate::refine::{Objective, RefineWorkspace};
use crate::topology::Machine;
use crate::Block;

/// GPU-IM configuration.
#[derive(Clone, Debug)]
pub struct GpuImConfig {
    /// Refinement iteration limit (12).
    pub iter_limit: usize,
    /// Coarsening stage (scheme, rounds, level cap, salt) — shared with
    /// every other multilevel pipeline.
    pub coarsen: CoarsenConfig,
    /// Initial-partitioning flavor (CPU multisection).
    pub init: SharedMapConfig,
    /// Ablation A2: use `J` for the rebalance loss instead of edge-cut.
    pub rebalance_with_comm_obj: bool,
    /// Cooperative cancellation, polled at every coarsening-level
    /// boundary and inside each Jet refinement round.
    pub cancel: crate::cancel::CancelToken,
}

impl Default for GpuImConfig {
    fn default() -> Self {
        GpuImConfig {
            iter_limit: 12,
            coarsen: CoarsenConfig::device(),
            // The coarsest graph is tiny (<= 8*k vertices): afford the
            // default multilevel effort for the initial mapping.
            init: SharedMapConfig {
                ml: crate::initial::MlConfig::default(),
                final_refine_rounds: 2,
                adaptive: true,
                cancel: crate::cancel::CancelToken::default(),
            },
            rebalance_with_comm_obj: false,
            cancel: crate::cancel::CancelToken::default(),
        }
    }
}

/// Run GPU-IM. Returns the vertex → PE mapping; `phases` collects the
/// Table-2 breakdown.
pub fn gpu_im(
    pool: &Pool,
    g: &CsrGraph,
    m: &Machine,
    eps: f64,
    seed: u64,
    cfg: &GpuImConfig,
    phases: Option<&mut PhaseBreakdown>,
) -> Vec<Block> {
    gpu_im_with(pool, g, m, eps, seed, cfg, phases, None)
}

/// [`gpu_im`] over an optional prebuilt hierarchy (the engine's
/// hierarchy cache). `prebuilt` must have been built for this graph with
/// `cfg.coarsen` and this machine's `(k, eps)`; when `None`, the
/// hierarchy is built here (and its build phases land in `phases`).
#[allow(clippy::too_many_arguments)]
pub fn gpu_im_with(
    pool: &Pool,
    g: &CsrGraph,
    m: &Machine,
    eps: f64,
    seed: u64,
    cfg: &GpuImConfig,
    mut phases: Option<&mut PhaseBreakdown>,
    prebuilt: Option<&CoarseHierarchy>,
) -> Vec<Block> {
    let k = m.k();
    let total = g.total_vweight();
    let lmax = l_max(total, k, eps);

    let mut owned = None;
    let Some(hier) = CoarseHierarchy::resolve(
        prebuilt,
        &mut owned,
        pool,
        g,
        k,
        lmax,
        &cfg.coarsen,
        &cfg.cancel,
        phases.as_deref_mut(),
    ) else {
        // Cancelled mid-coarsening: the engine discards the result, so
        // bail with a valid assignment.
        return vec![0 as Block; g.n()];
    };

    // Initial mapping on the CPU (paper: hierarchical multisection; GPU
    // offers no advantage at this size). `cfg.init` carries the same
    // cancel token, so the multisection bails at its own boundaries.
    let mapping = {
        let run = || sharedmap(hier.coarsest(), m, eps, seed ^ 0xabcd, &cfg.init);
        match phases.as_deref_mut() {
            Some(p) => p.time_cpu(Phase::InitialPartitioning, run),
            None => run(),
        }
    };

    let jet_cfg = JetConfig {
        iter_limit: cfg.iter_limit,
        filter: Filter::NonNegative,
        rebalance_with_comm_obj: cfg.rebalance_with_comm_obj,
        seed,
        cancel: cfg.cancel.clone(),
        ..Default::default()
    };
    // One workspace for the whole uncoarsening chain, sized at the finest
    // level so coarser levels never reallocate.
    let mut ws = RefineWorkspace::with_capacity(g.n(), k);
    // Uncoarsening: project + refine per level. A cancelled run still
    // projects to the finest level (the mapping must stay structurally
    // valid) but skips the per-level refinement.
    let mapping = hier.uncoarsen(pool, mapping, phases.as_deref_mut(), |_lev, gl, el, p| {
        if !cfg.cancel.is_cancelled() {
            jet_refine_with(pool, gl, el, p, k, lmax, &Objective::Comm(m), &jet_cfg, &mut ws);
        }
    });
    // Modeled D2H download of the final mapping.
    match phases.as_deref_mut() {
        Some(p) => p.time(Phase::Misc, || crate::par::ledger::charge(1, mapping.len() as u64)),
        None => crate::par::ledger::charge(1, mapping.len() as u64),
    }
    mapping
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::multilevel::BuildParams;
    use std::sync::Arc;
    use crate::partition::{comm_cost, is_balanced, validate_mapping};

    #[test]
    fn balanced_valid_mapping() {
        let g = gen::grid2d(40, 40, false);
        let h = Machine::hier("4:8", "1:10").unwrap();
        let pool = Pool::new(1);
        let m = gpu_im(&pool, &g, &h, 0.03, 1, &GpuImConfig::default(), None);
        validate_mapping(&m, g.n(), h.k()).unwrap();
        assert!(
            is_balanced(&g, &m, h.k(), 0.04),
            "imbalance {}",
            crate::partition::imbalance(&g, &m, h.k())
        );
    }

    #[test]
    fn quality_between_random_and_sharedmap() {
        let g = gen::delaunay_like(60, 3);
        let h = Machine::hier("4:8:2", "1:10:100").unwrap();
        let pool = Pool::new(1);
        let m = gpu_im(&pool, &g, &h, 0.03, 2, &GpuImConfig::default(), None);
        let j_im = comm_cost(&g, &m, &h);
        let m_sm = sharedmap(&g, &h, 0.03, 2, &SharedMapConfig::strong());
        let j_sm = comm_cost(&g, &m_sm, &h);
        let mut rng = crate::rng::Rng::new(4);
        let random: Vec<Block> = (0..g.n()).map(|_| rng.below(h.k() as u64) as Block).collect();
        let j_rnd = comm_cost(&g, &random, &h);
        // Paper: GPU-IM ≈ 33% above SharedMap-S; far better than random.
        assert!(j_im < j_rnd * 0.5, "not better than random: {j_im} vs {j_rnd}");
        assert!(j_im <= j_sm * 2.2, "too far from sharedmap: {j_im} vs {j_sm}");
    }

    #[test]
    fn table2_phases_all_present() {
        let g = gen::rgg(8_000, 0.04, 5);
        let h = Machine::hier("4:8:2", "1:10:100").unwrap();
        let pool = Pool::new(1);
        let mut phases = PhaseBreakdown::default();
        let _ = gpu_im(&pool, &g, &h, 0.03, 1, &GpuImConfig::default(), Some(&mut phases));
        for ph in [Phase::Coarsening, Phase::Contraction, Phase::InitialPartitioning, Phase::Uncontraction, Phase::RefineRebalance, Phase::Misc] {
            assert!(phases.device_ms(ph) > 0.0, "phase {:?} empty", ph);
        }
        // Refinement is the dominant phase (paper: 45–65%).
        assert!(phases.share(Phase::RefineRebalance) > 20.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = gen::stencil9(25, 25, 7);
        let h = Machine::hier("4:4", "1:10").unwrap();
        let pool = Pool::new(1);
        let a = gpu_im(&pool, &g, &h, 0.03, 9, &GpuImConfig::default(), None);
        let b = gpu_im(&pool, &g, &h, 0.03, 9, &GpuImConfig::default(), None);
        assert_eq!(a, b);
    }

    #[test]
    fn cluster_scheme_end_to_end() {
        // The cluster coarsener must carry a full GPU-IM run on a mesh
        // just like matching does.
        let g = gen::grid2d(36, 36, false);
        let h = Machine::hier("4:4", "1:10").unwrap();
        let pool = Pool::new(1);
        let cfg = GpuImConfig {
            coarsen: CoarsenConfig {
                scheme: crate::multilevel::SchemeKind::Cluster,
                ..CoarsenConfig::device()
            },
            ..GpuImConfig::default()
        };
        let m = gpu_im(&pool, &g, &h, 0.03, 3, &cfg, None);
        validate_mapping(&m, g.n(), h.k()).unwrap();
        assert!(is_balanced(&g, &m, h.k(), 0.05));
    }

    #[test]
    fn prebuilt_hierarchy_is_bit_identical_to_inline_build() {
        let g = gen::stencil9(30, 30, 2);
        let h = Machine::hier("4:4", "1:10").unwrap();
        let pool = Pool::new(1);
        let cfg = GpuImConfig::default();
        let params = BuildParams {
            coarsest: cfg.coarsen.coarsest_for(h.k()),
            lmax: l_max(g.total_vweight(), h.k(), 0.03),
            seed: cfg.coarsen.salt,
        };
        let hier = CoarseHierarchy::build(
            &pool,
            Arc::new(g.clone()),
            &params,
            &cfg.coarsen,
            &crate::cancel::CancelToken::new(),
            None,
        )
        .unwrap();
        hier.validate().unwrap();
        let fresh = gpu_im(&pool, &g, &h, 0.03, 11, &cfg, None);
        let reused = gpu_im_with(&pool, &g, &h, 0.03, 11, &cfg, None, Some(&hier));
        assert_eq!(fresh, reused, "cached-hierarchy path must be bit-identical");
    }
}
