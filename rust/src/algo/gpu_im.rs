//! GPU-IM — integrated mapping inside the multilevel pipeline
//! (paper §4.2; the paper's fastest algorithm).
//!
//! Device preference matching with the `expansion*²` rating (+ two-hop),
//! CAS-hash contraction (Alg. 3), CPU hierarchical-multisection initial
//! mapping on the ≤ 8·k coarsest graph, parallel uncontraction, and the
//! Jet-adapted refinement driven by the mapping gain Eq. 1 (Alg. 4–6)
//! with the non-negative first filter.

use super::sharedmap::{sharedmap, SharedMapConfig};
use crate::coarsen::contract_cas::contract_cas;
use crate::coarsen::{matched_fraction, matching_to_map, match_par::preference_matching, twohop::twohop_matching};
use crate::graph::{CsrGraph, EdgeList};
use crate::metrics::{Phase, PhaseBreakdown};
use crate::par::Pool;
use crate::partition::l_max;
use crate::refine::jet_loop::{jet_refine_with, JetConfig};
use crate::refine::jet_lp::Filter;
use crate::refine::{Objective, RefineWorkspace};
use crate::topology::Machine;
use crate::{Block, Vertex};

/// GPU-IM configuration.
#[derive(Clone, Debug)]
pub struct GpuImConfig {
    /// Refinement iteration limit (12).
    pub iter_limit: usize,
    /// Coarsen until `coarsest_factor · k` vertices (paper: 8).
    pub coarsest_factor: usize,
    /// Matching rounds per level.
    pub match_rounds: usize,
    /// Initial-partitioning flavor (CPU multisection).
    pub init: SharedMapConfig,
    /// Ablation A2: use `J` for the rebalance loss instead of edge-cut.
    pub rebalance_with_comm_obj: bool,
    /// Cooperative cancellation, polled at every coarsening-level
    /// boundary and inside each Jet refinement round.
    pub cancel: crate::cancel::CancelToken,
}

impl Default for GpuImConfig {
    fn default() -> Self {
        GpuImConfig {
            iter_limit: 12,
            coarsest_factor: 8,
            match_rounds: 8,
            // The coarsest graph is tiny (<= 8*k vertices): afford the
            // default multilevel effort for the initial mapping.
            init: SharedMapConfig {
                ml: crate::initial::MlConfig::default(),
                final_refine_rounds: 2,
                adaptive: true,
                cancel: crate::cancel::CancelToken::default(),
            },
            rebalance_with_comm_obj: false,
            cancel: crate::cancel::CancelToken::default(),
        }
    }
}

/// Run GPU-IM. Returns the vertex → PE mapping; `phases` collects the
/// Table-2 breakdown.
pub fn gpu_im(
    pool: &Pool,
    g: &CsrGraph,
    m: &Machine,
    eps: f64,
    seed: u64,
    cfg: &GpuImConfig,
    mut phases: Option<&mut PhaseBreakdown>,
) -> Vec<Block> {
    let k = m.k();
    let total = g.total_vweight();
    let lmax = l_max(total, k, eps);
    let coarsest = (cfg.coarsest_factor * k).max(64);

    macro_rules! timed {
        ($ph:expr, $e:expr) => {{
            match phases.as_deref_mut() {
                Some(p) => p.time($ph, || $e),
                None => $e,
            }
        }};
    }
    macro_rules! timed_cpu {
        ($ph:expr, $e:expr) => {{
            match phases.as_deref_mut() {
                Some(p) => p.time_cpu($ph, || $e),
                None => $e,
            }
        }};
    }

    // Coarsening (matching = "Coarsening" row, contraction separate).
    let mut graphs: Vec<CsrGraph> = vec![];
    let mut edge_lists: Vec<EdgeList> = vec![];
    let mut maps: Vec<Vec<Vertex>> = vec![];
    let mut cur = g.clone();
    // Misc charges include the ECSR build and the (simulated) host↔device
    // transfers of the input graph and the resulting mapping.
    let mut cur_el = timed!(Phase::Misc, {
        // Modeled H2D upload of the CSR graph (xadj + adj + weights).
        crate::par::ledger::charge(3, (cur.n() + 2 * cur.num_directed()) as u64);
        EdgeList::build_par(pool, &cur)
    });
    let mut level = 0u64;
    while cur.n() > coarsest {
        // Coarsening-level cancellation boundary: the engine discards the
        // result of a cancelled run, so bail with a valid assignment.
        if cfg.cancel.is_cancelled() {
            return vec![0 as Block; g.n()];
        }
        let mut mate = timed!(
            Phase::Coarsening,
            preference_matching(&cur, pool, lmax, seed ^ (level << 32), cfg.match_rounds)
        );
        if matched_fraction(&mate) < 0.75 {
            timed_cpu!(Phase::Coarsening, {
                twohop_matching(&cur, &mut mate, lmax);
            });
        }
        let (map, nc) = matching_to_map(&mate);
        if nc as f64 > cur.n() as f64 * 0.96 {
            break;
        }
        let coarse = timed!(Phase::Contraction, contract_cas(pool, &cur, &cur_el, &map, nc));
        let coarse_el = timed!(Phase::Misc, EdgeList::build_par(pool, &coarse));
        graphs.push(cur);
        edge_lists.push(cur_el);
        maps.push(map);
        cur = coarse;
        cur_el = coarse_el;
        level += 1;
    }

    // Initial mapping on the CPU (paper: hierarchical multisection; GPU
    // offers no advantage at this size). `cfg.init` carries the same
    // cancel token, so the multisection bails at its own boundaries.
    let mut mapping = timed_cpu!(
        Phase::InitialPartitioning,
        sharedmap(&cur, m, eps, seed ^ 0xabcd, &cfg.init)
    );

    let jet_cfg = JetConfig {
        iter_limit: cfg.iter_limit,
        filter: Filter::NonNegative,
        rebalance_with_comm_obj: cfg.rebalance_with_comm_obj,
        seed,
        cancel: cfg.cancel.clone(),
        ..Default::default()
    };

    // One workspace for the whole uncoarsening chain, sized at the finest
    // level so coarser levels never reallocate.
    let mut ws = RefineWorkspace::with_capacity(g.n(), k);

    // Refine the coarsest level.
    if !cfg.cancel.is_cancelled() {
        timed!(Phase::RefineRebalance, {
            jet_refine_with(
                pool, &cur, &cur_el, &mut mapping, k, lmax, &Objective::Comm(m), &jet_cfg, &mut ws,
            )
        });
    }

    // Uncoarsening. A cancelled run still projects down to the finest
    // level (the mapping must stay structurally valid) but skips the
    // per-level refinement.
    for lev in (0..maps.len()).rev() {
        let fine = &graphs[lev];
        let el = &edge_lists[lev];
        let map = &maps[lev];
        let mut fine_mapping = vec![0 as Block; fine.n()];
        timed!(Phase::Uncontraction, {
            let fp = crate::par::SharedMut::new(&mut fine_mapping);
            pool.parallel_for(fine.n(), |v| unsafe {
                fp.write(v, mapping[map[v] as usize]);
            });
        });
        if !cfg.cancel.is_cancelled() {
            timed!(Phase::RefineRebalance, {
                jet_refine_with(
                    pool, fine, el, &mut fine_mapping, k, lmax, &Objective::Comm(m), &jet_cfg,
                    &mut ws,
                )
            });
        }
        mapping = fine_mapping;
    }
    // Modeled D2H download of the final mapping.
    timed!(Phase::Misc, crate::par::ledger::charge(1, mapping.len() as u64));
    mapping
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{comm_cost, is_balanced, validate_mapping};

    #[test]
    fn balanced_valid_mapping() {
        let g = gen::grid2d(40, 40, false);
        let h = Machine::hier("4:8", "1:10").unwrap();
        let pool = Pool::new(1);
        let m = gpu_im(&pool, &g, &h, 0.03, 1, &GpuImConfig::default(), None);
        validate_mapping(&m, g.n(), h.k()).unwrap();
        assert!(
            is_balanced(&g, &m, h.k(), 0.04),
            "imbalance {}",
            crate::partition::imbalance(&g, &m, h.k())
        );
    }

    #[test]
    fn quality_between_random_and_sharedmap() {
        let g = gen::delaunay_like(60, 3);
        let h = Machine::hier("4:8:2", "1:10:100").unwrap();
        let pool = Pool::new(1);
        let m = gpu_im(&pool, &g, &h, 0.03, 2, &GpuImConfig::default(), None);
        let j_im = comm_cost(&g, &m, &h);
        let m_sm = sharedmap(&g, &h, 0.03, 2, &SharedMapConfig::strong());
        let j_sm = comm_cost(&g, &m_sm, &h);
        let mut rng = crate::rng::Rng::new(4);
        let random: Vec<Block> = (0..g.n()).map(|_| rng.below(h.k() as u64) as Block).collect();
        let j_rnd = comm_cost(&g, &random, &h);
        // Paper: GPU-IM ≈ 33% above SharedMap-S; far better than random.
        assert!(j_im < j_rnd * 0.5, "not better than random: {j_im} vs {j_rnd}");
        assert!(j_im <= j_sm * 2.2, "too far from sharedmap: {j_im} vs {j_sm}");
    }

    #[test]
    fn table2_phases_all_present() {
        let g = gen::rgg(8_000, 0.04, 5);
        let h = Machine::hier("4:8:2", "1:10:100").unwrap();
        let pool = Pool::new(1);
        let mut phases = PhaseBreakdown::default();
        let _ = gpu_im(&pool, &g, &h, 0.03, 1, &GpuImConfig::default(), Some(&mut phases));
        for ph in [Phase::Coarsening, Phase::Contraction, Phase::InitialPartitioning, Phase::Uncontraction, Phase::RefineRebalance, Phase::Misc] {
            assert!(phases.device_ms(ph) > 0.0, "phase {:?} empty", ph);
        }
        // Refinement is the dominant phase (paper: 45–65%).
        assert!(phases.share(Phase::RefineRebalance) > 20.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = gen::stencil9(25, 25, 7);
        let h = Machine::hier("4:4", "1:10").unwrap();
        let pool = Pool::new(1);
        let a = gpu_im(&pool, &g, &h, 0.03, 9, &GpuImConfig::default(), None);
        let b = gpu_im(&pool, &g, &h, 0.03, 9, &GpuImConfig::default(), None);
        assert_eq!(a, b);
    }
}
