//! IntMap-like serial integrated mapping (Faraj et al., SEA 2020).
//!
//! Integrates the mapping objective `J(C, D, Π)` into a serial multilevel
//! pipeline — the serial build of the unified [`crate::multilevel`]
//! subsystem (matching or cluster coarsening), hierarchical multisection
//! as initial mapping, and J-objective label propagation during
//! uncoarsening. The Fast/Strong flavors mirror IntMap's configurations.

use super::sharedmap::{sharedmap, SharedMapConfig};
use crate::graph::CsrGraph;
use crate::multilevel::{BuildParams, CoarsenConfig, CoarseHierarchy};
use crate::partition::l_max;
use crate::refine::{
    lp_serial::{force_balance_serial, lp_refine_serial},
    Objective,
};
use crate::topology::Machine;
use crate::Block;

/// Configuration of the serial integrated mapper.
#[derive(Clone, Debug)]
pub struct IntMapConfig {
    /// Coarsening stage (scheme, level cap `max(factor · k, min)`) —
    /// shared with every other multilevel pipeline. The per-level seeds
    /// derive from the job seed (serial runs are not hierarchy-cached).
    pub coarsen: CoarsenConfig,
    /// LP refinement rounds per level.
    pub lp_rounds: usize,
    /// Extra LP rounds on the finest level.
    pub finest_extra_rounds: usize,
    /// Multisection flavor for the initial mapping.
    pub init: SharedMapConfig,
    /// Cooperative cancellation, polled at every coarsening and
    /// uncoarsening level boundary.
    pub cancel: crate::cancel::CancelToken,
}

impl IntMapConfig {
    pub fn fast() -> Self {
        IntMapConfig {
            coarsen: CoarsenConfig::serial(400),
            lp_rounds: 2,
            finest_extra_rounds: 0,
            init: SharedMapConfig::fast(),
            cancel: crate::cancel::CancelToken::default(),
        }
    }

    pub fn strong() -> Self {
        IntMapConfig {
            coarsen: CoarsenConfig::serial(400),
            lp_rounds: 6,
            finest_extra_rounds: 6,
            init: SharedMapConfig::strong(),
            cancel: crate::cancel::CancelToken::default(),
        }
    }
}

/// Serial integrated mapping. Returns the vertex → PE mapping.
pub fn intmap(g: &CsrGraph, m: &Machine, eps: f64, seed: u64, cfg: &IntMapConfig) -> Vec<Block> {
    let k = m.k();
    let total = g.total_vweight();
    let lmax = l_max(total, k, eps);

    let params = BuildParams { coarsest: cfg.coarsen.coarsest_for(k), lmax, seed };
    let Some(hier) = CoarseHierarchy::build_serial(g, &params, &cfg.coarsen, &cfg.cancel) else {
        // Cancelled mid-coarsening: any structurally valid mapping will
        // do — the engine discards it.
        return vec![0 as Block; g.n()];
    };

    // Initial mapping: hierarchical multisection on the coarsest graph.
    let mapping = sharedmap(hier.coarsest(), m, eps, seed ^ 0xabcd, &cfg.init);

    // Uncoarsening with J-objective label propagation. The coarsest
    // level repairs balance explicitly first (coarse vertex weights are
    // chunky relative to L_max). A cancelled run still projects to the
    // finest level but skips the refinement.
    let coarsest_level = hier.levels();
    hier.uncoarsen_serial(mapping, |lev, gl, part| {
        if cfg.cancel.is_cancelled() {
            return;
        }
        if lev == coarsest_level {
            force_balance_serial(gl, part, k, lmax, &Objective::Comm(m), seed ^ 2);
            lp_refine_serial(gl, part, k, lmax, &Objective::Comm(m), cfg.lp_rounds, seed ^ 1);
        } else {
            let rounds =
                if lev == 0 { cfg.lp_rounds + cfg.finest_extra_rounds } else { cfg.lp_rounds };
            force_balance_serial(gl, part, k, lmax, &Objective::Comm(m), seed ^ 3);
            lp_refine_serial(gl, part, k, lmax, &Objective::Comm(m), rounds, seed ^ (lev as u64) << 16);
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{comm_cost, is_balanced, validate_mapping};

    #[test]
    fn balanced_valid_mapping() {
        let g = gen::grid2d(30, 30, false);
        let h = Machine::hier("4:8", "1:10").unwrap();
        let m = intmap(&g, &h, 0.03, 1, &IntMapConfig::fast());
        validate_mapping(&m, g.n(), h.k()).unwrap();
        assert!(is_balanced(&g, &m, h.k(), 0.035));
    }

    #[test]
    fn close_to_sharedmap_quality() {
        // The paper orders quality SharedMap-S < IntMap-S (worse) — IntMap
        // should land within ~1.4× of SharedMap-S on mesh graphs.
        let g = gen::delaunay_like(40, 2);
        let h = Machine::hier("4:4:2", "1:10:100").unwrap();
        let j_im = comm_cost(&g, &intmap(&g, &h, 0.03, 3, &IntMapConfig::strong()), &h);
        let j_sm = comm_cost(
            &g,
            &sharedmap(&g, &h, 0.03, 3, &SharedMapConfig::strong()),
            &h,
        );
        assert!(j_im <= j_sm * 1.45, "intmap {j_im} vs sharedmap {j_sm}");
    }

    #[test]
    fn strong_not_worse_than_fast() {
        let g = gen::stencil9(25, 25, 4);
        let h = Machine::hier("4:4", "1:10").unwrap();
        let jf = comm_cost(&g, &intmap(&g, &h, 0.03, 5, &IntMapConfig::fast()), &h);
        let js = comm_cost(&g, &intmap(&g, &h, 0.03, 5, &IntMapConfig::strong()), &h);
        assert!(js <= jf * 1.10, "strong {js} vs fast {jf}");
    }

    #[test]
    fn works_when_graph_smaller_than_coarsest_bound() {
        let g = gen::grid2d(10, 10, false);
        let h = Machine::hier("2:2", "1:10").unwrap();
        let m = intmap(&g, &h, 0.10, 2, &IntMapConfig::fast());
        validate_mapping(&m, g.n(), 4).unwrap();
    }
}
