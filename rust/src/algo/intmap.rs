//! IntMap-like serial integrated mapping (Faraj et al., SEA 2020).
//!
//! Integrates the mapping objective `J(C, D, Π)` into a serial multilevel
//! pipeline: matching-based coarsening (`expansion*` rating family),
//! hierarchical multisection as initial mapping, and J-objective label
//! propagation during uncoarsening. The Fast/Strong flavors mirror
//! IntMap's configurations.

use super::sharedmap::{sharedmap, SharedMapConfig};
use crate::coarsen::coarsen_step_serial;
use crate::graph::CsrGraph;
use crate::partition::l_max;
use crate::refine::{
    lp_serial::{force_balance_serial, lp_refine_serial},
    Objective,
};
use crate::topology::Machine;
use crate::{Block, Vertex};

/// Configuration of the serial integrated mapper.
#[derive(Clone, Debug)]
pub struct IntMapConfig {
    /// Coarsen until `max(coarsest_factor · k, coarsest_min)` vertices.
    pub coarsest_factor: usize,
    pub coarsest_min: usize,
    /// LP refinement rounds per level.
    pub lp_rounds: usize,
    /// Extra LP rounds on the finest level.
    pub finest_extra_rounds: usize,
    /// Multisection flavor for the initial mapping.
    pub init: SharedMapConfig,
    /// Cooperative cancellation, polled at every coarsening and
    /// uncoarsening level boundary.
    pub cancel: crate::cancel::CancelToken,
}

impl IntMapConfig {
    pub fn fast() -> Self {
        IntMapConfig {
            coarsest_factor: 8,
            coarsest_min: 400,
            lp_rounds: 2,
            finest_extra_rounds: 0,
            init: SharedMapConfig::fast(),
            cancel: crate::cancel::CancelToken::default(),
        }
    }

    pub fn strong() -> Self {
        IntMapConfig {
            coarsest_factor: 8,
            coarsest_min: 400,
            lp_rounds: 6,
            finest_extra_rounds: 6,
            init: SharedMapConfig::strong(),
            cancel: crate::cancel::CancelToken::default(),
        }
    }
}

/// Serial integrated mapping. Returns the vertex → PE mapping.
pub fn intmap(g: &CsrGraph, m: &Machine, eps: f64, seed: u64, cfg: &IntMapConfig) -> Vec<Block> {
    let k = m.k();
    let total = g.total_vweight();
    let lmax = l_max(total, k, eps);
    let coarsest = (cfg.coarsest_factor * k).max(cfg.coarsest_min);

    // Coarsening.
    let mut graphs: Vec<CsrGraph> = vec![];
    let mut maps: Vec<Vec<Vertex>> = vec![];
    let mut cur = g.clone();
    let mut level = 0u64;
    while cur.n() > coarsest {
        // Coarsening-level cancellation boundary.
        if cfg.cancel.is_cancelled() {
            return vec![0 as Block; g.n()];
        }
        let (coarse, map) = coarsen_step_serial(&cur, lmax, seed ^ (level << 24));
        if coarse.n() as f64 > cur.n() as f64 * 0.96 {
            break;
        }
        graphs.push(cur);
        maps.push(map);
        cur = coarse;
        level += 1;
    }

    // Initial mapping: hierarchical multisection on the coarsest graph.
    // Coarse vertex weights are chunky relative to L_max, so repair the
    // balance explicitly before refining.
    let mut mapping = sharedmap(&cur, m, eps, seed ^ 0xabcd, &cfg.init);
    if !cfg.cancel.is_cancelled() {
        force_balance_serial(&cur, &mut mapping, k, lmax, &Objective::Comm(m), seed ^ 2);
        lp_refine_serial(&cur, &mut mapping, k, lmax, &Objective::Comm(m), cfg.lp_rounds, seed ^ 1);
    }

    // Uncoarsening with J-objective label propagation. A cancelled run
    // still projects to the finest level but skips the refinement.
    for lev in (0..maps.len()).rev() {
        let fine = &graphs[lev];
        let map = &maps[lev];
        let mut fine_mapping = vec![0 as Block; fine.n()];
        for v in 0..fine.n() {
            fine_mapping[v] = mapping[map[v] as usize];
        }
        if !cfg.cancel.is_cancelled() {
            let rounds = if lev == 0 { cfg.lp_rounds + cfg.finest_extra_rounds } else { cfg.lp_rounds };
            force_balance_serial(fine, &mut fine_mapping, k, lmax, &Objective::Comm(m), seed ^ 3);
            lp_refine_serial(fine, &mut fine_mapping, k, lmax, &Objective::Comm(m), rounds, seed ^ (lev as u64) << 16);
        }
        mapping = fine_mapping;
    }
    mapping
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{comm_cost, is_balanced, validate_mapping};

    #[test]
    fn balanced_valid_mapping() {
        let g = gen::grid2d(30, 30, false);
        let h = Machine::hier("4:8", "1:10").unwrap();
        let m = intmap(&g, &h, 0.03, 1, &IntMapConfig::fast());
        validate_mapping(&m, g.n(), h.k()).unwrap();
        assert!(is_balanced(&g, &m, h.k(), 0.035));
    }

    #[test]
    fn close_to_sharedmap_quality() {
        // The paper orders quality SharedMap-S < IntMap-S (worse) — IntMap
        // should land within ~1.4× of SharedMap-S on mesh graphs.
        let g = gen::delaunay_like(40, 2);
        let h = Machine::hier("4:4:2", "1:10:100").unwrap();
        let j_im = comm_cost(&g, &intmap(&g, &h, 0.03, 3, &IntMapConfig::strong()), &h);
        let j_sm = comm_cost(
            &g,
            &sharedmap(&g, &h, 0.03, 3, &SharedMapConfig::strong()),
            &h,
        );
        assert!(j_im <= j_sm * 1.45, "intmap {j_im} vs sharedmap {j_sm}");
    }

    #[test]
    fn strong_not_worse_than_fast() {
        let g = gen::stencil9(25, 25, 4);
        let h = Machine::hier("4:4", "1:10").unwrap();
        let jf = comm_cost(&g, &intmap(&g, &h, 0.03, 5, &IntMapConfig::fast()), &h);
        let js = comm_cost(&g, &intmap(&g, &h, 0.03, 5, &IntMapConfig::strong()), &h);
        assert!(js <= jf * 1.10, "strong {js} vs fast {jf}");
    }

    #[test]
    fn works_when_graph_smaller_than_coarsest_bound() {
        let g = gen::grid2d(10, 10, false);
        let h = Machine::hier("2:2", "1:10").unwrap();
        let m = intmap(&g, &h, 0.10, 2, &IntMapConfig::fast());
        validate_mapping(&m, g.n(), 4).unwrap();
    }
}
