//! Graph patches: delta updates to a pinned session graph.
//!
//! A [`GraphPatch`] is an ordered list of [`PatchOp`]s parsed from the
//! wire grammar (comma-separated, colon-delimited fields):
//!
//! | op           | meaning                                              |
//! |--------------|------------------------------------------------------|
//! | `ae:u:v:w`   | add undirected edge `{u,v}` with weight `w`          |
//! | `re:u:v`     | remove edge `{u,v}`                                  |
//! | `ew:u:v:w`   | set the weight of existing edge `{u,v}` to `w`       |
//! | `vw:v:w`     | set the weight of vertex `v` to `w`                  |
//! | `av:w`       | append an isolated vertex (id `n`) with weight `w`   |
//! | `rv:v`       | remove isolated vertex `v` (ids above shift down)    |
//!
//! # Invariants
//!
//! * Ops apply **sequentially**; each op sees the graph produced by the
//!   previous one (so `av:1,ae:0:<n>:1.0` is well-formed).
//! * The patched graph satisfies every [`CsrGraph`] invariant: edges
//!   stored twice, adjacency strictly sorted, symmetric weights, no
//!   self-loops. Applying a patch and rebuilding the same edge set from
//!   scratch produce byte-identical CSR arrays (see [`fingerprint`] and
//!   the property test in `tests/incremental.rs`).
//! * Edge weights must be finite and positive; vertex weights must be
//!   non-negative. `ae` on an existing edge, `re`/`ew` on a missing one,
//!   and `rv` on a non-isolated vertex are errors — a patch either
//!   applies completely or not at all (apply works on a copy).
//! * Weights-only patches (`ew`/`vw` ops exclusively) take a fast path
//!   that clones the CSR arrays and edits weights in place — no rebuild
//!   and no re-sort. (`CsrGraph` owns its buffers, so "structural
//!   sharing" here means skipping the rebuild, not aliasing memory.)

use crate::graph::CsrGraph;
use crate::{EWeight, VWeight, Vertex};
use std::collections::BTreeSet;
use std::fmt;

/// One delta operation (see the module docs for the wire grammar).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PatchOp {
    /// `ae:u:v:w` — add undirected edge `{u,v}` (must not exist).
    AddEdge { u: Vertex, v: Vertex, w: EWeight },
    /// `re:u:v` — remove edge `{u,v}` (must exist).
    RemoveEdge { u: Vertex, v: Vertex },
    /// `ew:u:v:w` — set the weight of existing edge `{u,v}`.
    SetEdgeWeight { u: Vertex, v: Vertex, w: EWeight },
    /// `vw:v:w` — set the weight of vertex `v`.
    SetVertexWeight { v: Vertex, w: VWeight },
    /// `av:w` — append an isolated vertex with weight `w`; its id is the
    /// current `n`.
    AddVertex { w: VWeight },
    /// `rv:v` — remove vertex `v`, which must be isolated; every id
    /// above `v` shifts down by one.
    RemoveVertex { v: Vertex },
}

impl PatchOp {
    /// Structural vertex-set change (`av`/`rv`) or vertex reweight —
    /// anything that changes `n` or total vertex weight. These force a
    /// cold remap and invalidate every cached hierarchy level (coarse
    /// vertex weights, and thus `L_max`, change).
    pub fn is_vertex_op(&self) -> bool {
        matches!(
            self,
            PatchOp::SetVertexWeight { .. } | PatchOp::AddVertex { .. } | PatchOp::RemoveVertex { .. }
        )
    }

    /// True for ops that keep the adjacency structure (`ew`/`vw`).
    pub fn is_weight_only(&self) -> bool {
        matches!(self, PatchOp::SetEdgeWeight { .. } | PatchOp::SetVertexWeight { .. })
    }
}

/// An ordered sequence of [`PatchOp`]s (the module docs give the wire
/// grammar and the apply invariants).
#[derive(Clone, Debug, PartialEq)]
pub struct GraphPatch {
    pub ops: Vec<PatchOp>,
}

/// The result of applying a patch: the new graph plus what the remapper
/// needs to plan a warm start.
pub struct Applied {
    /// The patched graph (validated invariants).
    pub graph: CsrGraph,
    /// Endpoints touched by the patch, in **new** vertex ids, sorted and
    /// deduplicated. Seed set for the halo region.
    pub touched: Vec<Vertex>,
    /// Whether any op changed the vertex set or a vertex weight.
    pub vertex_ops: bool,
    /// Whether every op was `ew`/`vw` (fast path; adjacency unchanged).
    pub weights_only: bool,
}

/// What `Engine::patch_graph` reports back to the wire layer.
#[derive(Clone, Debug)]
pub struct PatchSummary {
    pub n: usize,
    pub m: usize,
    /// New session version of the pinned graph.
    pub version: u64,
    /// Number of touched vertices (new ids).
    pub touched: usize,
    /// Number of ops applied.
    pub ops: usize,
}

/// Typed patch failure, mapped to wire error codes by the coordinator
/// (`unknown_graph` / `patch`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PatchError {
    /// No pinned session graph under that name.
    UnknownGraph(String),
    /// Grammar or apply error (out-of-range vertex, duplicate edge, …).
    Invalid(String),
}

impl fmt::Display for PatchError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PatchError::UnknownGraph(name) => write!(f, "unknown session graph `{name}`"),
            PatchError::Invalid(msg) => write!(f, "invalid patch: {msg}"),
        }
    }
}

impl std::error::Error for PatchError {}

fn parse_vertex(s: &str, op: &str) -> Result<Vertex, String> {
    s.parse::<Vertex>().map_err(|_| format!("{op}: bad vertex id `{s}`"))
}

fn parse_eweight(s: &str, op: &str) -> Result<EWeight, String> {
    let w = s.parse::<EWeight>().map_err(|_| format!("{op}: bad edge weight `{s}`"))?;
    if !w.is_finite() || w <= 0.0 {
        return Err(format!("{op}: edge weight must be finite and positive, got `{s}`"));
    }
    Ok(w)
}

fn parse_vweight(s: &str, op: &str) -> Result<VWeight, String> {
    let w = s.parse::<VWeight>().map_err(|_| format!("{op}: bad vertex weight `{s}`"))?;
    if w < 0 {
        return Err(format!("{op}: vertex weight must be non-negative, got `{s}`"));
    }
    Ok(w)
}

impl GraphPatch {
    /// Parse the wire grammar: comma-separated ops, colon-delimited
    /// fields (`ae:0:5:1.5,re:2:3,vw:7:4`). Empty input is an error.
    pub fn parse(s: &str) -> Result<GraphPatch, String> {
        let mut ops = Vec::new();
        for raw in s.split(',') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            let fields: Vec<&str> = raw.split(':').collect();
            let op = match fields.as_slice() {
                ["ae", u, v, w] => PatchOp::AddEdge {
                    u: parse_vertex(u, "ae")?,
                    v: parse_vertex(v, "ae")?,
                    w: parse_eweight(w, "ae")?,
                },
                ["re", u, v] => {
                    PatchOp::RemoveEdge { u: parse_vertex(u, "re")?, v: parse_vertex(v, "re")? }
                }
                ["ew", u, v, w] => PatchOp::SetEdgeWeight {
                    u: parse_vertex(u, "ew")?,
                    v: parse_vertex(v, "ew")?,
                    w: parse_eweight(w, "ew")?,
                },
                ["vw", v, w] => PatchOp::SetVertexWeight {
                    v: parse_vertex(v, "vw")?,
                    w: parse_vweight(w, "vw")?,
                },
                ["av", w] => PatchOp::AddVertex { w: parse_vweight(w, "av")? },
                ["rv", v] => PatchOp::RemoveVertex { v: parse_vertex(v, "rv")? },
                [tag, ..] => return Err(format!("unknown patch op `{tag}` in `{raw}`")),
                [] => unreachable!("split yields at least one field"),
            };
            if let PatchOp::AddEdge { u, v, .. } | PatchOp::SetEdgeWeight { u, v, .. } = op {
                if u == v {
                    return Err(format!("self loop `{raw}` not allowed"));
                }
            }
            ops.push(op);
        }
        if ops.is_empty() {
            return Err("empty patch".into());
        }
        Ok(GraphPatch { ops })
    }

    /// Whether any op changes the vertex set or a vertex weight (forces
    /// a cold remap; see [`PatchOp::is_vertex_op`]).
    pub fn has_vertex_ops(&self) -> bool {
        self.ops.iter().any(|op| op.is_vertex_op())
    }

    /// Whether every op keeps the adjacency structure intact.
    pub fn is_weights_only(&self) -> bool {
        self.ops.iter().all(|op| op.is_weight_only())
    }

    /// Edge endpoints named by edge ops (`ae`/`re`/`ew`), in patch order.
    pub fn edge_pairs(&self) -> Vec<(Vertex, Vertex)> {
        self.ops
            .iter()
            .filter_map(|op| match *op {
                PatchOp::AddEdge { u, v, .. }
                | PatchOp::RemoveEdge { u, v }
                | PatchOp::SetEdgeWeight { u, v, .. } => Some((u, v)),
                _ => None,
            })
            .collect()
    }

    /// Apply the patch to `g`, producing a new validated graph. `g` is
    /// untouched; an error leaves no side effects (all-or-nothing).
    pub fn apply(&self, g: &CsrGraph) -> Result<Applied, String> {
        if self.is_weights_only() {
            return self.apply_weights_only(g);
        }

        // General path: explode into per-vertex adjacency vectors (kept
        // sorted by binary-search insertion/removal), apply sequentially,
        // reassemble CSR.
        let mut adjs: Vec<Vec<(Vertex, EWeight)>> = (0..g.n())
            .map(|v| {
                let (nbrs, ws) = g.neighbors_w(v as Vertex);
                nbrs.iter().copied().zip(ws.iter().copied()).collect()
            })
            .collect();
        let mut vw = g.vw.clone();
        let mut touched: BTreeSet<Vertex> = BTreeSet::new();

        for op in &self.ops {
            match *op {
                PatchOp::AddEdge { u, v, w } => {
                    check_range(u, vw.len(), "ae")?;
                    check_range(v, vw.len(), "ae")?;
                    let iu = match adjs[u as usize].binary_search_by_key(&v, |e| e.0) {
                        Ok(_) => return Err(format!("ae:{u}:{v}: edge already exists")),
                        Err(i) => i,
                    };
                    adjs[u as usize].insert(iu, (v, w));
                    let iv = adjs[v as usize]
                        .binary_search_by_key(&u, |e| e.0)
                        .expect_err("reverse slot mirrors forward");
                    adjs[v as usize].insert(iv, (u, w));
                    touched.insert(u);
                    touched.insert(v);
                }
                PatchOp::RemoveEdge { u, v } => {
                    check_range(u, vw.len(), "re")?;
                    check_range(v, vw.len(), "re")?;
                    let iu = adjs[u as usize]
                        .binary_search_by_key(&v, |e| e.0)
                        .map_err(|_| format!("re:{u}:{v}: no such edge"))?;
                    adjs[u as usize].remove(iu);
                    let iv = adjs[v as usize]
                        .binary_search_by_key(&u, |e| e.0)
                        .expect("reverse slot mirrors forward");
                    adjs[v as usize].remove(iv);
                    touched.insert(u);
                    touched.insert(v);
                }
                PatchOp::SetEdgeWeight { u, v, w } => {
                    check_range(u, vw.len(), "ew")?;
                    check_range(v, vw.len(), "ew")?;
                    let iu = adjs[u as usize]
                        .binary_search_by_key(&v, |e| e.0)
                        .map_err(|_| format!("ew:{u}:{v}: no such edge"))?;
                    adjs[u as usize][iu].1 = w;
                    let iv = adjs[v as usize]
                        .binary_search_by_key(&u, |e| e.0)
                        .expect("reverse slot mirrors forward");
                    adjs[v as usize][iv].1 = w;
                    touched.insert(u);
                    touched.insert(v);
                }
                PatchOp::SetVertexWeight { v, w } => {
                    check_range(v, vw.len(), "vw")?;
                    vw[v as usize] = w;
                    touched.insert(v);
                }
                PatchOp::AddVertex { w } => {
                    let id = vw.len() as Vertex;
                    vw.push(w);
                    adjs.push(Vec::new());
                    touched.insert(id);
                }
                PatchOp::RemoveVertex { v } => {
                    check_range(v, vw.len(), "rv")?;
                    if !adjs[v as usize].is_empty() {
                        return Err(format!("rv:{v}: vertex is not isolated"));
                    }
                    adjs.remove(v as usize);
                    vw.remove(v as usize);
                    // Ids above v shift down, everywhere.
                    for list in adjs.iter_mut() {
                        for e in list.iter_mut() {
                            if e.0 > v {
                                e.0 -= 1;
                            }
                        }
                    }
                    touched = touched
                        .into_iter()
                        .filter(|&t| t != v)
                        .map(|t| if t > v { t - 1 } else { t })
                        .collect();
                }
            }
        }

        // Reassemble CSR. Adjacency lists stayed sorted throughout.
        let n = vw.len();
        let mut xadj = vec![0u32; n + 1];
        for v in 0..n {
            xadj[v + 1] = xadj[v] + adjs[v].len() as u32;
        }
        let total = xadj[n] as usize;
        let mut adj = Vec::with_capacity(total);
        let mut ew = Vec::with_capacity(total);
        for list in &adjs {
            for &(t, w) in list {
                adj.push(t);
                ew.push(w);
            }
        }
        let graph = CsrGraph { xadj, adj, ew, vw };
        debug_assert_eq!(graph.validate(), Ok(()));
        Ok(Applied {
            graph,
            touched: touched.into_iter().collect(),
            vertex_ops: self.has_vertex_ops(),
            weights_only: false,
        })
    }

    /// Fast path for `ew`/`vw`-only patches: adjacency arrays are cloned
    /// verbatim and weights edited in place (both directed slots).
    fn apply_weights_only(&self, g: &CsrGraph) -> Result<Applied, String> {
        let mut out = g.clone();
        let mut touched: BTreeSet<Vertex> = BTreeSet::new();
        let mut vertex_ops = false;
        for op in &self.ops {
            match *op {
                PatchOp::SetEdgeWeight { u, v, w } => {
                    check_range(u, out.n(), "ew")?;
                    check_range(v, out.n(), "ew")?;
                    set_slot(&mut out, u, v, w).ok_or(format!("ew:{u}:{v}: no such edge"))?;
                    set_slot(&mut out, v, u, w).expect("reverse slot mirrors forward");
                    touched.insert(u);
                    touched.insert(v);
                }
                PatchOp::SetVertexWeight { v, w } => {
                    check_range(v, out.n(), "vw")?;
                    out.vw[v as usize] = w;
                    touched.insert(v);
                    vertex_ops = true;
                }
                _ => unreachable!("weights-only path sees only ew/vw"),
            }
        }
        debug_assert_eq!(out.validate(), Ok(()));
        Ok(Applied {
            graph: out,
            touched: touched.into_iter().collect(),
            vertex_ops,
            weights_only: true,
        })
    }
}

fn check_range(v: Vertex, n: usize, op: &str) -> Result<(), String> {
    if (v as usize) < n {
        Ok(())
    } else {
        Err(format!("{op}: vertex {v} out of range (n={n})"))
    }
}

/// Set the weight of directed slot `u -> v`; `None` if the edge is absent.
fn set_slot(g: &mut CsrGraph, u: Vertex, v: Vertex, w: EWeight) -> Option<()> {
    let base = g.xadj[u as usize] as usize;
    let i = g.neighbors(u).binary_search(&v).ok()?;
    g.ew[base + i] = w;
    Some(())
}

/// Order-sensitive FNV-1a fingerprint of the full CSR representation
/// (`n`, offsets, targets, edge-weight bits, vertex weights). Two graphs
/// with identical CSR arrays — e.g. a patched graph and a from-scratch
/// rebuild of the same edge set — fingerprint identically.
pub fn fingerprint(g: &CsrGraph) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    fn mix(h: &mut u64, x: u64) {
        for b in x.to_le_bytes() {
            *h = (*h ^ b as u64).wrapping_mul(PRIME);
        }
    }
    let mut h: u64 = 0xcbf29ce484222325;
    mix(&mut h, g.n() as u64);
    for &x in &g.xadj {
        mix(&mut h, x as u64);
    }
    for &t in &g.adj {
        mix(&mut h, t as u64);
    }
    for &w in &g.ew {
        mix(&mut h, w.to_bits());
    }
    for &w in &g.vw {
        mix(&mut h, w as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::from_edges;
    use crate::graph::gen;

    fn ring4() -> CsrGraph {
        from_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 0, 1.0)], None)
    }

    #[test]
    fn parse_roundtrips_every_op() {
        let p = GraphPatch::parse("ae:0:5:1.5,re:2:3,ew:1:4:2.25,vw:7:4,av:2,rv:6").unwrap();
        assert_eq!(p.ops.len(), 6);
        assert_eq!(p.ops[0], PatchOp::AddEdge { u: 0, v: 5, w: 1.5 });
        assert_eq!(p.ops[3], PatchOp::SetVertexWeight { v: 7, w: 4 });
        assert!(p.has_vertex_ops());
        assert!(!p.is_weights_only());
        assert_eq!(p.edge_pairs(), vec![(0, 5), (2, 3), (1, 4)]);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(GraphPatch::parse("").is_err());
        assert!(GraphPatch::parse("xx:1:2").is_err());
        assert!(GraphPatch::parse("ae:1:2").is_err(), "missing weight");
        assert!(GraphPatch::parse("ae:1:1:1.0").is_err(), "self loop");
        assert!(GraphPatch::parse("ae:0:1:-2.0").is_err(), "negative edge weight");
        assert!(GraphPatch::parse("ae:0:1:nan").is_err(), "non-finite weight");
        assert!(GraphPatch::parse("vw:0:-1").is_err(), "negative vertex weight");
        assert!(GraphPatch::parse("ae:0:x:1.0").is_err(), "bad vertex");
    }

    #[test]
    fn add_and_remove_edges() {
        let g = ring4();
        let p = GraphPatch::parse("ae:0:2:2.5,re:1:2").unwrap();
        let a = p.apply(&g).unwrap();
        a.graph.validate().unwrap();
        assert_eq!(a.graph.m(), 4);
        assert_eq!(a.graph.find_edge(0, 2), Some(2.5));
        assert_eq!(a.graph.find_edge(1, 2), None);
        assert_eq!(a.touched, vec![0, 1, 2]);
        assert!(!a.vertex_ops);
    }

    #[test]
    fn weights_only_fast_path_keeps_structure() {
        let g = ring4();
        let p = GraphPatch::parse("ew:0:1:9.0,vw:3:5").unwrap();
        let a = p.apply(&g).unwrap();
        assert!(a.weights_only);
        assert!(a.vertex_ops, "vw counts as a vertex op");
        assert_eq!(a.graph.xadj, g.xadj);
        assert_eq!(a.graph.adj, g.adj);
        assert_eq!(a.graph.find_edge(0, 1), Some(9.0));
        assert_eq!(a.graph.find_edge(1, 0), Some(9.0));
        assert_eq!(a.graph.vw[3], 5);
        a.graph.validate().unwrap();
    }

    #[test]
    fn vertex_add_remove_shifts_ids() {
        let g = ring4();
        // Append vertex 4, wire it to 0, then drop vertex 2 (must be
        // isolated first).
        let p = GraphPatch::parse("av:3,ae:0:4:1.0,re:1:2,re:2:3,rv:2").unwrap();
        let a = p.apply(&g).unwrap();
        a.graph.validate().unwrap();
        assert_eq!(a.graph.n(), 4);
        // Old ids 3, 4 became 2, 3.
        assert_eq!(a.graph.vw, vec![1, 1, 1, 3]);
        assert_eq!(a.graph.find_edge(2, 0), Some(1.0), "old edge 3-0");
        assert_eq!(a.graph.find_edge(0, 3), Some(1.0), "old edge 0-4");
        assert!(a.vertex_ops);
    }

    #[test]
    fn apply_errors_are_total() {
        let g = ring4();
        for bad in ["ae:0:1:1.0", "re:0:2", "ew:0:2:1.0", "rv:1", "ae:0:9:1.0", "vw:9:1"] {
            let p = GraphPatch::parse(bad).unwrap();
            assert!(p.apply(&g).is_err(), "`{bad}` must fail");
        }
    }

    #[test]
    fn patched_matches_from_scratch_rebuild() {
        let g = gen::rgg(300, 0.1, 11);
        let (u, v) = (0u32, (g.n() - 1) as u32);
        assert_eq!(g.find_edge(u, v), None, "rgg endpoints far apart");
        let first = g.neighbors(5)[0];
        let p = GraphPatch::parse(&format!("ae:{u}:{v}:1.25,re:5:{first}")).unwrap();
        let a = p.apply(&g).unwrap();
        // Rebuild from scratch with the same edge set.
        let mut edges = Vec::new();
        for x in 0..g.n() as Vertex {
            let (nbrs, ws) = g.neighbors_w(x);
            for (&y, &w) in nbrs.iter().zip(ws) {
                if x < y && !(x == 5 && y == first) && !(x == first && y == 5) {
                    edges.push((x, y, w));
                }
            }
        }
        edges.push((u, v, 1.25));
        let rebuilt = from_edges(g.n(), &edges, Some(g.vw.clone()));
        assert_eq!(fingerprint(&a.graph), fingerprint(&rebuilt));
        assert_eq!(a.graph.xadj, rebuilt.xadj);
        assert_eq!(a.graph.adj, rebuilt.adj);
    }

    #[test]
    fn fingerprint_is_weight_sensitive() {
        let g = ring4();
        let h = GraphPatch::parse("ew:0:1:2.0").unwrap().apply(&g).unwrap().graph;
        assert_ne!(fingerprint(&g), fingerprint(&h));
        let same = GraphPatch::parse("ew:0:1:1.0").unwrap().apply(&g).unwrap().graph;
        assert_eq!(fingerprint(&g), fingerprint(&same));
    }
}
