//! Incremental remapping & batched submission.
//!
//! Serving sustained traffic means most jobs arrive against a graph the
//! engine has already mapped, usually with only a small delta since the
//! last request. This subsystem turns that observation into latency:
//!
//! * [`patch`] — [`GraphPatch`]: delta edge/vertex updates applied to a
//!   pinned session graph (`graph patch name=… ops=…` on the wire),
//!   producing a new validated graph version without re-uploading.
//! * [`remap`] — [`Remapper`]: keeps the last mapping per session graph
//!   and plans **warm** restarts (one Jet refinement pass seeded from
//!   the previous mapping, arXiv 2107.02539) versus **cold** full
//!   solves, gated by the halo-expanded affected region; plus
//!   [`level_validity_mask`], which lets the engine's hierarchy cache
//!   keep the coarse levels a patch provably did not change
//!   (arXiv 2001.07134).
//! * [`batch`] — compatibility rules and drain limits for
//!   `Engine::submit_batch`, which packs many small same-machine jobs
//!   into one worker pass.

pub mod batch;
pub mod patch;
pub mod remap;

pub use batch::{compatible, BATCH_DRAIN_MAX, BATCH_SMALL_N};
pub use patch::{fingerprint, Applied, GraphPatch, PatchError, PatchOp, PatchSummary};
pub use remap::{halo_region, level_validity_mask, warm_refine, RemapKind, RemapPlan, Remapper};
