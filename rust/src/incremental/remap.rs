//! Incremental remapping: warm-start refinement after a graph patch.
//!
//! The [`Remapper`] keeps the last mapping produced for each pinned
//! session graph. When the graph is patched and mapped again, the engine
//! asks for a [`RemapPlan`]:
//!
//! * **Warm** — a prior mapping exists for the same machine/`k`/version
//!   lineage, the patch kept the vertex set intact, and the affected
//!   region (touched vertices plus a `remap.halo`-hop halo, default 1)
//!   covers at most `remap.max_region_frac` of the graph (default 0.25).
//!   The engine then skips coarsen→initial→uncoarsen entirely and runs
//!   one Jet refinement pass ([`warm_refine`]) seeded with the previous
//!   mapping — the re-map-from-warm-start strategy of the dynamic
//!   process-mapping line (arXiv 2107.02539).
//! * **Cold** — a remap is pending but the warm conditions fail (first
//!   map after a patch with no prior mapping, vertex-set change, region
//!   too large, different machine): full multilevel solve.
//! * **Skip** — nothing pending (no patch since the last map): the plain
//!   solve path, untouched.
//!
//! # Invariants
//!
//! * [`Remapper::record`] stores only full-length mappings (`len == n`);
//!   the engine records after polish so the warm start always seeds from
//!   the best known mapping.
//! * [`Remapper::note_patch`] accumulates touched vertices across
//!   multiple patches until the next map; a vertex-set change poisons
//!   the state (forced cold) because stored mappings are positional.
//! * [`Remapper::plan`] never mutates state: a cancelled or failed warm
//!   job leaves the pending patch intact for the next attempt.
//! * Warm results are exact, not approximations: `RefineStats::
//!   final_objective` is a full exact reduction, and the mapping is
//!   rebalanced by Jet's weak/strong rebalancer if the patch broke the
//!   balance constraint.
//!
//! Hierarchy-level reuse rides along via [`level_validity_mask`]: a
//! patch whose edge ops are all intra-cluster at level `l` leaves the
//! level-`l..` coarse graphs byte-identical (contraction drops
//! intra-cluster edges as self-loops), so the engine re-keys the cached
//! hierarchy to the patched graph instead of discarding it — the
//! level-restricted reuse argument of the hierarchical-mapping line
//! (arXiv 2001.07134).

use super::patch::GraphPatch;
use crate::cancel::CancelToken;
use crate::graph::{CsrGraph, EdgeList};
use crate::multilevel::CoarseHierarchy;
use crate::par::Pool;
use crate::refine::jet_loop::{jet_refine_with, JetConfig, RefineStats};
use crate::refine::{Objective, RefineWorkspace};
use crate::topology::Machine;
use crate::{Block, Vertex};
use std::collections::HashMap;

/// How a job's mapping was produced relative to the session history.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RemapKind {
    /// Warm-start refinement from the previous mapping.
    Warm,
    /// Pending remap fell back to a full multilevel solve.
    Cold,
}

impl RemapKind {
    pub fn name(self) -> &'static str {
        match self {
            RemapKind::Warm => "warm",
            RemapKind::Cold => "cold",
        }
    }
}

/// The engine's decision for one job (see the module docs).
#[derive(Clone, Debug)]
pub enum RemapPlan {
    /// No remap pending — plain solve path.
    Skip,
    /// Remap pending, warm conditions failed — full solve, tagged cold.
    Cold,
    /// Warm-start refinement from `start` (full previous mapping);
    /// `region` is the halo-expanded affected-vertex count that passed
    /// the threshold.
    Warm { start: Vec<Block>, region: usize },
}

/// Per-session-graph remap state.
struct RemapState {
    /// Session version the state was recorded/updated against.
    version: u64,
    n: usize,
    k: usize,
    /// Canonical machine spec string ([`Machine::spec_string`]).
    machine_spec: String,
    /// Last full mapping; empty = poisoned (vertex-set change or a
    /// patch landed before any map).
    mapping: Vec<Block>,
    /// Touched vertices accumulated since the last map (sorted, dedup).
    touched: Vec<Vertex>,
    /// Whether a patch landed since the last map.
    pending: bool,
}

/// Keeps the last mapping per pinned session graph and plans warm
/// restarts (module docs have the full contract).
#[derive(Default)]
pub struct Remapper {
    states: HashMap<String, RemapState>,
}

impl Remapper {
    pub fn new() -> Self {
        Remapper::default()
    }

    /// Record the mapping a finished job produced for session graph
    /// `name` at `version`. Clears any pending patch state. Ignores
    /// truncated mappings (`len != n`).
    pub fn record(
        &mut self,
        name: &str,
        version: u64,
        n: usize,
        k: usize,
        machine_spec: &str,
        mapping: &[Block],
    ) {
        if mapping.len() != n {
            return;
        }
        self.states.insert(
            name.to_string(),
            RemapState {
                version,
                n,
                k,
                machine_spec: machine_spec.to_string(),
                mapping: mapping.to_vec(),
                touched: Vec::new(),
                pending: false,
            },
        );
    }

    /// Note a patch on session graph `name`: bump to `new_version`,
    /// accumulate `touched` (new-id space), and poison the stored
    /// mapping when the vertex set changed (`vertex_ops` or a new `n`).
    pub fn note_patch(
        &mut self,
        name: &str,
        new_version: u64,
        new_n: usize,
        touched: &[Vertex],
        vertex_ops: bool,
    ) {
        let state = self.states.entry(name.to_string()).or_insert_with(|| RemapState {
            version: new_version,
            n: new_n,
            k: 0,
            machine_spec: String::new(),
            mapping: Vec::new(),
            touched: Vec::new(),
            pending: false,
        });
        if vertex_ops || state.n != new_n {
            state.mapping.clear();
        }
        state.version = new_version;
        state.n = new_n;
        state.pending = true;
        state.touched.extend_from_slice(touched);
        state.touched.sort_unstable();
        state.touched.dedup();
    }

    /// Drop all state for `name` (graph replaced via `graph put`, or
    /// dropped).
    pub fn forget(&mut self, name: &str) {
        self.states.remove(name);
    }

    /// Plan the next job on session graph `name` at store `version`.
    /// Read-only: a cancelled/failed warm attempt can re-plan later.
    pub fn plan(
        &self,
        name: &str,
        version: u64,
        g: &CsrGraph,
        k: usize,
        machine_spec: &str,
        halo: usize,
        max_region_frac: f64,
    ) -> RemapPlan {
        let Some(state) = self.states.get(name) else {
            return RemapPlan::Skip;
        };
        if !state.pending {
            return RemapPlan::Skip;
        }
        if state.version != version
            || state.n != g.n()
            || state.mapping.len() != g.n()
            || state.k != k
            || state.machine_spec != machine_spec
        {
            return RemapPlan::Cold;
        }
        let region = halo_region(g, &state.touched, halo);
        if g.n() == 0 || region.len() as f64 > max_region_frac * g.n() as f64 {
            return RemapPlan::Cold;
        }
        RemapPlan::Warm { start: state.mapping.clone(), region: region.len() }
    }
}

/// The affected region: `touched` plus every vertex within `hops` BFS
/// hops of it (out-of-range seeds are ignored). Sorted, deduplicated.
pub fn halo_region(g: &CsrGraph, touched: &[Vertex], hops: usize) -> Vec<Vertex> {
    let n = g.n();
    let mut seen = vec![false; n];
    let mut frontier: Vec<Vertex> = Vec::new();
    for &v in touched {
        if (v as usize) < n && !seen[v as usize] {
            seen[v as usize] = true;
            frontier.push(v);
        }
    }
    let mut region = frontier.clone();
    for _ in 0..hops {
        let mut next = Vec::new();
        for &v in &frontier {
            for &u in g.neighbors(v) {
                if !seen[u as usize] {
                    seen[u as usize] = true;
                    next.push(u);
                }
            }
        }
        if next.is_empty() {
            break;
        }
        region.extend_from_slice(&next);
        frontier = next;
    }
    region.sort_unstable();
    region
}

/// Which levels of a cached hierarchy stay *exact* after `patch` on its
/// finest graph: bit `l` set ⇔ the level-`l` coarse graph (and every map
/// above it) is byte-identical on the patched graph. Bit 0 (the finest
/// graph itself) is always clear. Any vertex op clears everything —
/// coarse vertex weights, and through `L_max` the cache key itself,
/// change. An edge op is harmless at level `l` iff both endpoints fall
/// in the same level-`l` cluster (the edge contracts to a dropped
/// self-loop); validity is therefore upward-closed: once intra-cluster,
/// always intra-cluster on coarser levels.
pub fn level_validity_mask(hier: &CoarseHierarchy, patch: &GraphPatch) -> u64 {
    if patch.has_vertex_ops() {
        return 0;
    }
    let levels = hier.levels();
    if levels == 0 {
        return 0;
    }
    let n = hier.finest().n();
    let pairs = patch.edge_pairs();
    if pairs.iter().any(|&(u, v)| u as usize >= n || v as usize >= n) {
        return 0;
    }
    // comp[v] = cluster of finest vertex v at the current level.
    let mut comp: Vec<Vertex> = (0..n as Vertex).collect();
    let top = levels.min(u64::BITS as usize - 1);
    for lev in 0..top {
        let map = hier.map(lev);
        for c in comp.iter_mut() {
            *c = map[*c as usize];
        }
        if pairs.iter().all(|&(u, v)| comp[u as usize] == comp[v as usize]) {
            let mut mask = 0u64;
            for l in (lev + 1)..=top {
                mask |= 1u64 << l;
            }
            return mask;
        }
    }
    0
}

/// One warm Jet refinement pass: build the edge list, seed from `part`
/// (the previous mapping) and refine toward `J(C, D, Π)` under
/// `machine`. Replaces the whole coarsen→initial→uncoarsen pipeline on
/// the warm path; `RefineStats::final_objective` is an exact reduction
/// of the returned mapping.
#[allow(clippy::too_many_arguments)]
pub fn warm_refine(
    pool: &Pool,
    g: &CsrGraph,
    part: &mut Vec<Block>,
    machine: &Machine,
    eps: f64,
    seed: u64,
    cancel: CancelToken,
) -> RefineStats {
    let el = EdgeList::build_par(pool, g);
    let k = machine.k();
    let lmax = crate::partition::l_max(g.total_vweight(), k, eps);
    let mut ws = RefineWorkspace::with_capacity(g.n(), k);
    let cfg = JetConfig { seed, cancel, ..Default::default() };
    jet_refine_with(pool, g, &el, part, k, lmax, &Objective::Comm(machine), &cfg, &mut ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::multilevel::{BuildParams, CoarsenConfig};
    use crate::partition::{comm_cost, is_balanced};

    fn grid() -> CsrGraph {
        gen::grid2d(20, 20, false)
    }

    #[test]
    fn halo_grows_by_hops() {
        let g = grid();
        let r0 = halo_region(&g, &[0], 0);
        assert_eq!(r0, vec![0]);
        let r1 = halo_region(&g, &[0], 1);
        assert_eq!(r1.len(), 3, "corner vertex + 2 neighbors");
        let r2 = halo_region(&g, &[0], 2);
        assert!(r2.len() > r1.len());
        // Out-of-range seeds ignored; duplicates deduped.
        assert_eq!(halo_region(&g, &[0, 0, 9_999_999], 0), vec![0]);
    }

    #[test]
    fn plan_states() {
        let g = grid();
        let mut r = Remapper::new();
        let spec = "hier:2:2/1:10";
        // Nothing known → Skip.
        assert!(matches!(r.plan("s", 1, &g, 4, spec, 1, 0.25), RemapPlan::Skip));
        // Mapping recorded, no patch → Skip.
        r.record("s", 1, g.n(), 4, spec, &vec![0; g.n()]);
        assert!(matches!(r.plan("s", 1, &g, 4, spec, 1, 0.25), RemapPlan::Skip));
        // Small patch → Warm with the recorded start.
        r.note_patch("s", 2, g.n(), &[0, 1], false);
        match r.plan("s", 2, &g, 4, spec, 1, 0.25) {
            RemapPlan::Warm { start, region } => {
                assert_eq!(start.len(), g.n());
                assert!(region >= 2);
            }
            other => panic!("expected warm, got {other:?}"),
        }
        // plan() is read-only: still warm on a retry.
        assert!(matches!(r.plan("s", 2, &g, 4, spec, 1, 0.25), RemapPlan::Warm { .. }));
        // Version/machine/k mismatches → Cold.
        assert!(matches!(r.plan("s", 3, &g, 4, spec, 1, 0.25), RemapPlan::Cold));
        assert!(matches!(r.plan("s", 2, &g, 8, spec, 1, 0.25), RemapPlan::Cold));
        assert!(matches!(r.plan("s", 2, &g, 4, "torus:2x2", 1, 0.25), RemapPlan::Cold));
        // Region too large → Cold.
        let all: Vec<Vertex> = (0..g.n() as Vertex).collect();
        r.note_patch("s", 3, g.n(), &all, false);
        assert!(matches!(r.plan("s", 3, &g, 4, spec, 1, 0.25), RemapPlan::Cold));
        // record() clears pending.
        r.record("s", 3, g.n(), 4, spec, &vec![0; g.n()]);
        assert!(matches!(r.plan("s", 3, &g, 4, spec, 1, 0.25), RemapPlan::Skip));
        // forget() drops everything.
        r.note_patch("s", 4, g.n(), &[1], false);
        r.forget("s");
        assert!(matches!(r.plan("s", 4, &g, 4, spec, 1, 0.25), RemapPlan::Skip));
    }

    #[test]
    fn vertex_ops_poison_the_mapping() {
        let g = grid();
        let mut r = Remapper::new();
        let spec = "hier:2:2/1:10";
        r.record("s", 1, g.n(), 4, spec, &vec![0; g.n()]);
        r.note_patch("s", 2, g.n(), &[5], true);
        assert!(matches!(r.plan("s", 2, &g, 4, spec, 1, 0.25), RemapPlan::Cold));
        // Patch before any map → Cold too.
        r.forget("s");
        r.note_patch("s", 1, g.n(), &[5], false);
        assert!(matches!(r.plan("s", 1, &g, 4, spec, 1, 0.25), RemapPlan::Cold));
    }

    #[test]
    fn truncated_mapping_is_not_recorded() {
        let g = grid();
        let mut r = Remapper::new();
        let spec = "hier:2:2/1:10";
        r.record("s", 1, g.n(), 4, spec, &[0, 1, 2]);
        r.note_patch("s", 2, g.n(), &[0], false);
        assert!(matches!(r.plan("s", 2, &g, 4, spec, 1, 0.25), RemapPlan::Cold));
    }

    #[test]
    fn validity_mask_tracks_cluster_boundaries() {
        let g = gen::rgg(2_000, 0.05, 3);
        let cfg = CoarsenConfig::device();
        let params = BuildParams { coarsest: 64, lmax: i64::MAX, seed: cfg.salt };
        let pool = Pool::new(1);
        let h = CoarseHierarchy::build(
            &pool,
            std::sync::Arc::new(g.clone()),
            &params,
            &cfg,
            &CancelToken::new(),
            None,
        )
        .unwrap();
        assert!(h.levels() >= 2, "need a real hierarchy");
        // An edge between two vertices merged at level 1 keeps every
        // level except the finest.
        let map0 = h.map(0);
        let mut pair = None;
        'outer: for v in 0..g.n() as Vertex {
            for u in (v + 1)..g.n() as Vertex {
                if map0[v as usize] == map0[u as usize] && g.find_edge(v, u).is_none() {
                    pair = Some((v, u));
                    break 'outer;
                }
            }
        }
        let (v, u) = pair.expect("some cluster has a non-adjacent pair");
        let p = GraphPatch::parse(&format!("ae:{v}:{u}:1.0")).unwrap();
        let mask = level_validity_mask(&h, &p);
        assert_eq!(mask & 1, 0, "finest level never valid");
        for l in 1..=h.levels().min(63) {
            assert_ne!(mask & (1 << l), 0, "level {l} should be valid");
        }
        // A vertex op invalidates everything.
        let pv = GraphPatch::parse("vw:0:3").unwrap();
        assert_eq!(level_validity_mask(&h, &pv), 0);
        // A cross-cluster edge at every level invalidates everything
        // (pick endpoints in different coarsest clusters).
        let mut comp: Vec<Vertex> = (0..g.n() as Vertex).collect();
        for lev in 0..h.levels() {
            let m = h.map(lev);
            for c in comp.iter_mut() {
                *c = m[*c as usize];
            }
        }
        let a = 0u32;
        let b = (0..g.n() as u32).find(|&x| comp[x as usize] != comp[0]).unwrap();
        if g.find_edge(a, b).is_none() {
            let p2 = GraphPatch::parse(&format!("ae:{a}:{b}:1.0")).unwrap();
            assert_eq!(level_validity_mask(&h, &p2), 0);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // miri: full jet refinement pass, too slow
    fn warm_refine_improves_and_balances() {
        let g = gen::rgg(1_200, 0.06, 5);
        let m = Machine::hier("2:2", "1:10").unwrap();
        let k = m.k();
        let pool = Pool::new(1);
        // Start from a mediocre but full mapping (striped).
        let mut part: Vec<Block> = (0..g.n()).map(|v| (v % k) as Block).collect();
        let before = comm_cost(&g, &part, &m);
        let stats =
            warm_refine(&pool, &g, &mut part, &m, 0.03, 1, CancelToken::new());
        let after = comm_cost(&g, &part, &m);
        assert!(is_balanced(&g, &part, k, 0.031));
        assert!(after <= before);
        assert!(
            (stats.final_objective - after).abs() <= 1e-6 * after.max(1.0),
            "reported {} vs recomputed {after}",
            stats.final_objective
        );
    }
}
