//! Batched job submission: compatibility rules and drain limits.
//!
//! `Engine::submit_batch` enqueues a group of specs as one unit (one
//! queue lock, consecutive sequence numbers, a shared batch id). When an
//! engine worker pops a batched job it greedily drains up to
//! [`BATCH_DRAIN_MAX`] − 1 more jobs from the queue head that belong to
//! the **same batch**, target the **same machine** ([`compatible`]) and
//! are **small** (`n` ≤ [`BATCH_SMALL_N`]), then runs the group in one
//! worker pass — amortizing condvar wakeups, queue traffic and pool
//! warm-up across jobs instead of paying them per job. Heterogeneous or
//! large jobs simply fall out of the drain and run as usual; draining
//! never reorders across priorities because only the queue head is
//! taken.

use crate::engine::MapSpec;

/// Jobs at or below this vertex count may be drained into a shared
/// worker pass (small solves are dominated by fixed per-job overhead).
pub const BATCH_SMALL_N: usize = 65_536;

/// Maximum number of jobs one worker runs per drain (including the job
/// it popped) — bounds the latency tail a batch can impose on the queue.
pub const BATCH_DRAIN_MAX: usize = 32;

/// Whether two specs may share one worker pass: identical machine
/// (topology override, hierarchy and distance strings) and imbalance.
/// Seeds, algorithms and solver options may differ — they don't change
/// the machine the pass maps onto.
pub fn compatible(a: &MapSpec, b: &MapSpec) -> bool {
    a.topology == b.topology
        && a.hierarchy == b.hierarchy
        && a.distance == b.distance
        && a.eps == b.eps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compatibility_is_machine_scoped() {
        let a = MapSpec::named("x").hierarchy("2:2").distance("1:10");
        let b = a.clone().seed(9);
        assert!(compatible(&a, &b), "seeds may differ");
        assert!(!compatible(&a, &a.clone().hierarchy("4:2")));
        assert!(!compatible(&a, &a.clone().distance("1:20")));
        assert!(!compatible(&a, &a.clone().eps(0.1)));
        assert!(!compatible(&a, &a.clone().topology_spec("torus:2x2")));
    }
}
