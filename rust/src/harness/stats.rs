//! Statistics helpers: geometric means, speedups, aggregation.

/// Geometric mean of strictly positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = xs.iter().map(|&x| x.max(1e-300).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Max of a slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Min of a slice.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Speedup vector `base[i] / other[i]`.
pub fn speedups(base: &[f64], other: &[f64]) -> Vec<f64> {
    base.iter().zip(other).map(|(&b, &o)| b / o.max(1e-12)).collect()
}

/// Summary of a speedup distribution: (geomean, max, min).
pub fn speedup_summary(base: &[f64], other: &[f64]) -> (f64, f64, f64) {
    let s = speedups(base, other);
    (geomean(&s), max(&s), min(&s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn speedup_summary_works() {
        let base = vec![100.0, 100.0];
        let fast = vec![10.0, 1.0];
        let (g, mx, mn) = speedup_summary(&base, &fast);
        assert!((mx - 100.0).abs() < 1e-9);
        assert!((mn - 10.0).abs() < 1e-9);
        assert!((g - (1000.0f64).sqrt()).abs() < 1e-6);
    }
}
