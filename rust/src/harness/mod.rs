//! Evaluation harness: performance profiles (Dolan–Moré), geometric-mean
//! speedups, the experiment matrix runner, and markdown/CSV emitters for
//! the paper's tables and figures.

pub mod profiles;
pub mod stats;

use crate::algo::Algorithm;
use crate::engine::{Engine, MapSpec};
use crate::graph::gen::InstanceSpec;
use crate::topology::Hierarchy;
use std::sync::Arc;

/// One (algorithm, instance, hierarchy) averaged over seeds.
#[derive(Clone, Debug)]
pub struct ExpRecord {
    pub algorithm: Algorithm,
    pub instance: String,
    pub group: String,
    pub large: bool,
    pub hierarchy: String,
    /// Mean communication cost over seeds.
    pub comm_cost: f64,
    /// Mean host wall time (ms).
    pub host_ms: f64,
    /// Mean modeled device time (ms) — wall time for CPU baselines.
    pub device_ms: f64,
    pub seeds: usize,
}

impl ExpRecord {
    pub fn csv_header() -> &'static str {
        "algorithm,instance,group,large,hierarchy,comm_cost,host_ms,device_ms,seeds"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{},{:.3},{:.3},{:.3},{}",
            self.algorithm.name(),
            self.instance,
            self.group,
            self.large,
            self.hierarchy,
            self.comm_cost,
            self.host_ms,
            self.device_ms,
            self.seeds
        )
    }
}

/// Run the full experiment matrix: `algorithms × instances × hierarchies`,
/// averaging over `seeds`. Each instance is generated once and fed to the
/// engine in memory; every cell goes through [`Engine::map`], so matrix
/// numbers are produced by exactly the code path the CLI and the service
/// use. Progress is printed to stderr.
pub fn run_matrix(
    engine: &Engine,
    algorithms: &[Algorithm],
    instances: &[InstanceSpec],
    hierarchies: &[Hierarchy],
    seeds: &[u64],
    eps: f64,
) -> Vec<ExpRecord> {
    let mut out = Vec::new();
    for spec in instances {
        let g = Arc::new(spec.generate());
        for h in hierarchies {
            for &algo in algorithms {
                let base = MapSpec::in_memory(g.clone())
                    .topology(h)
                    .eps(eps)
                    .algo(Some(algo))
                    .return_mapping(false)
                    .seeds(seeds.to_vec());
                let mut cost = 0.0;
                let mut host = 0.0;
                let mut device = 0.0;
                for r in engine.map_all_seeds(&base).expect("in-memory matrix cell") {
                    cost += r.comm_cost;
                    host += r.host_ms;
                    device += r.device_ms;
                }
                let ns = seeds.len() as f64;
                let rec = ExpRecord {
                    algorithm: algo,
                    instance: spec.name.to_string(),
                    group: spec.group.to_string(),
                    large: spec.size_class() == crate::graph::gen::SizeClass::Large,
                    hierarchy: h.label(),
                    comm_cost: cost / ns,
                    host_ms: host / ns,
                    device_ms: device / ns,
                    seeds: seeds.len(),
                };
                eprintln!(
                    "  [{}] {} {} J={:.0} host={:.1}ms dev={:.2}ms",
                    rec.algorithm.name(),
                    rec.instance,
                    rec.hierarchy,
                    rec.comm_cost,
                    rec.host_ms,
                    rec.device_ms
                );
                out.push(rec);
            }
        }
    }
    out
}

/// Write records as CSV.
pub fn write_csv(records: &[ExpRecord], path: &std::path::Path) -> anyhow::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", ExpRecord::csv_header())?;
    for r in records {
        writeln!(f, "{}", r.to_csv())?;
    }
    Ok(())
}

/// Seeds/hierarchy subsetting from the environment, so the full paper
/// matrix (5 seeds × 6 hierarchies) can be scaled to the host:
/// `HEIPA_SEEDS=1,2 HEIPA_TOPS=2,6`.
pub fn seeds_from_env(default: &[u64]) -> Vec<u64> {
    match std::env::var("HEIPA_SEEDS") {
        Ok(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        Err(_) => default.to_vec(),
    }
}

/// Hierarchies `4:8:t` for `t` from `HEIPA_TOPS` (default: the paper's 1..6).
pub fn hierarchies_from_env() -> Vec<Hierarchy> {
    let tops: Vec<u32> = match std::env::var("HEIPA_TOPS") {
        Ok(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        Err(_) => (1..=6).collect(),
    };
    tops.into_iter()
        .map(|t| Hierarchy::new(vec![4, 8, t], vec![1.0, 10.0, 100.0]).unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::smoke_suite;

    #[test]
    fn matrix_runs_and_emits_csv() {
        let engine = Engine::new(crate::engine::EngineConfig { threads: 1, ..Default::default() });
        let specs: Vec<_> = smoke_suite().into_iter().take(1).collect();
        let hs = vec![Hierarchy::parse("2:2", "1:10").unwrap()];
        let recs = run_matrix(&engine, &[Algorithm::GpuIm, Algorithm::SharedMapF], &specs, &hs, &[1], 0.03);
        assert_eq!(recs.len(), 2);
        for r in &recs {
            assert!(r.comm_cost > 0.0);
            assert!(r.to_csv().split(',').count() == ExpRecord::csv_header().split(',').count());
        }
    }

    #[test]
    fn env_defaults() {
        // (Do not set the env vars here: tests run in one process.)
        let seeds = seeds_from_env(&[1, 2, 3]);
        assert!(!seeds.is_empty());
        let hs = hierarchies_from_env();
        assert!(!hs.is_empty());
        assert!(hs.iter().all(|h| h.k() % 32 == 0));
    }
}
