//! Evaluation harness: performance profiles (Dolan–Moré), geometric-mean
//! speedups, the experiment matrix runner, and markdown/CSV emitters for
//! the paper's tables and figures.

pub mod profiles;
pub mod stats;

use crate::algo::Algorithm;
use crate::engine::{Engine, JobHandle, MapSpec, SubmitOpts};
use crate::graph::gen::InstanceSpec;
use crate::graph::{gen, CsrGraph};
use crate::topology::{Hierarchy, Machine};
use std::sync::Arc;

/// One (algorithm, instance, hierarchy) averaged over seeds.
#[derive(Clone, Debug)]
pub struct ExpRecord {
    pub algorithm: Algorithm,
    pub instance: String,
    pub group: String,
    pub large: bool,
    pub hierarchy: String,
    /// Mean communication cost over seeds.
    pub comm_cost: f64,
    /// Mean host wall time (ms).
    pub host_ms: f64,
    /// Mean modeled device time (ms) — wall time for CPU baselines.
    pub device_ms: f64,
    pub seeds: usize,
}

impl ExpRecord {
    pub fn csv_header() -> &'static str {
        "algorithm,instance,group,large,hierarchy,comm_cost,host_ms,device_ms,seeds"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{},{:.3},{:.3},{:.3},{}",
            self.algorithm.name(),
            self.instance,
            self.group,
            self.large,
            self.hierarchy,
            self.comm_cost,
            self.host_ms,
            self.device_ms,
            self.seeds
        )
    }
}

/// Run the full experiment matrix: `algorithms × instances × hierarchies`,
/// averaging over `seeds`. Each instance is generated once and fed to the
/// engine in memory; **the whole matrix is submitted to the engine's job
/// queue before the first wait**, so with `workers > 1` cells solve
/// concurrently — matrix numbers are produced by exactly the code path
/// the CLI and the service use, including the queue. Results aggregate in
/// matrix order regardless of completion order. Progress is printed to
/// stderr as cells complete.
pub fn run_matrix(
    engine: &Engine,
    algorithms: &[Algorithm],
    instances: &[InstanceSpec],
    machines: &[Machine],
    seeds: &[u64],
    eps: f64,
) -> Vec<ExpRecord> {
    struct Cell<'a> {
        spec: &'a InstanceSpec,
        machine: &'a Machine,
        algo: Algorithm,
        jobs: Vec<JobHandle>,
    }
    device_check_banner();
    // Phase 1: submit every (instance, machine, algorithm, seed) job.
    // Submission blocks on queue space (never drops cells), so a matrix
    // larger than `queue_cap` interleaves submission with execution.
    let mut cells: Vec<Cell> = Vec::new();
    for spec in instances {
        let g = Arc::new(spec.generate());
        for h in machines {
            for &algo in algorithms {
                let base = MapSpec::in_memory(g.clone())
                    .topology(h)
                    .eps(eps)
                    .algo(Some(algo))
                    .return_mapping(false)
                    .seeds(seeds.to_vec());
                let jobs = seeds
                    .iter()
                    .map(|&s| {
                        engine
                            .submit_opts(
                                &base.with_seed(s),
                                SubmitOpts { block_when_full: true, ..SubmitOpts::default() },
                            )
                            .expect("matrix submit (engine running)")
                    })
                    .collect();
                cells.push(Cell { spec, machine: h, algo, jobs });
            }
        }
    }
    // Phase 2: wait in matrix order and aggregate.
    let mut out = Vec::new();
    for cell in cells {
        let mut cost = 0.0;
        let mut host = 0.0;
        let mut device = 0.0;
        for job in cell.jobs {
            let r = job.wait().expect("in-memory matrix cell");
            cost += r.comm_cost;
            host += r.host_ms;
            device += r.device_ms;
        }
        let ns = seeds.len() as f64;
        let rec = ExpRecord {
            algorithm: cell.algo,
            instance: cell.spec.name.to_string(),
            group: cell.spec.group.to_string(),
            large: cell.spec.size_class() == crate::graph::gen::SizeClass::Large,
            // Model labels may contain commas (fat-tree arity
            // lists); keep the CSV column count stable.
            hierarchy: cell.machine.label().replace(',', ";"),
            comm_cost: cost / ns,
            host_ms: host / ns,
            device_ms: device / ns,
            seeds: seeds.len(),
        };
        eprintln!(
            "  [{}] {} {} J={:.0} host={:.1}ms dev={:.2}ms",
            rec.algorithm.name(),
            rec.instance,
            rec.hierarchy,
            rec.comm_cost,
            rec.host_ms,
            rec.device_ms
        );
        out.push(rec);
    }
    out
}

/// Write records as CSV.
pub fn write_csv(records: &[ExpRecord], path: &std::path::Path) -> anyhow::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", ExpRecord::csv_header())?;
    for r in records {
        writeln!(f, "{}", r.to_csv())?;
    }
    Ok(())
}

/// Report checked-device mode once per run. The `HEIPA_DEVICE_CHECK`
/// switch only has teeth when the `device-check` feature is compiled in;
/// a user who sets the variable on a normal build gets a loud warning
/// instead of silently-unchecked kernels. Returns whether the shadow
/// checker is live so callers can annotate their own output.
pub fn device_check_banner() -> bool {
    let active = crate::par::device_check_active();
    let requested = std::env::var("HEIPA_DEVICE_CHECK").map(|v| v != "0").unwrap_or(false);
    if active {
        eprintln!(
            "heipa: checked-device mode ON (shadow access log validates every kernel; \
             expect a large slowdown — timings are not comparable)"
        );
    } else if requested {
        eprintln!(
            "heipa: warning: HEIPA_DEVICE_CHECK is set but this binary was built without \
             `--features device-check`; kernels are NOT being checked"
        );
    }
    active
}

/// Seeds/machine subsetting from the environment, so the full paper
/// matrix (5 seeds × 6 hierarchies) can be scaled to the host:
/// `HEIPA_SEEDS=1,2 HEIPA_TOPS=2,6`.
pub fn seeds_from_env(default: &[u64]) -> Vec<u64> {
    match std::env::var("HEIPA_SEEDS") {
        Ok(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        Err(_) => default.to_vec(),
    }
}

/// Machines from `HEIPA_TOPS` (default: the paper's `4:8:{1..6}`
/// family). The value splits into chunks on `;`; a chunk containing `:`
/// is one full `topology=` spec string (`torus:4x4x4`, `fattree:…`, …
/// — specs may contain commas), any other chunk is a comma-separated
/// list of bare integers `t` (→ hierarchy `4:8:t / 1:10:100`). So both
/// the classic `HEIPA_TOPS=2,6` and
/// `HEIPA_TOPS='2,6;fattree:3:2,4,4/1,5,20'` work. Misconfigured
/// entries abort loudly.
pub fn machines_from_env() -> Vec<Machine> {
    let paper = |t: u32| {
        Machine::from(Hierarchy::new(vec![4, 8, t], vec![1.0, 10.0, 100.0]).unwrap())
    };
    match std::env::var("HEIPA_TOPS") {
        Ok(v) => v
            .split(';')
            .map(|s| s.trim())
            .filter(|s| !s.is_empty())
            .flat_map(|chunk| -> Vec<Machine> {
                if chunk.contains(':') {
                    vec![Machine::parse_spec(chunk)
                        .unwrap_or_else(|e| panic!("HEIPA_TOPS entry `{chunk}`: {e}"))]
                } else {
                    chunk
                        .split(',')
                        .map(|s| s.trim())
                        .filter(|s| !s.is_empty())
                        .map(|t| {
                            paper(t.parse::<u32>().unwrap_or_else(|_| {
                                panic!("HEIPA_TOPS entry `{t}`: not an integer top (specs need a `scheme:` prefix)")
                            }))
                        })
                        .collect()
                }
            })
            .collect(),
        Err(_) => (1..=6).map(paper).collect(),
    }
}

/// A benchmark scenario: a task graph paired with a machine model — the
/// non-hierarchical presets the bench smoke runs so torus/fat-tree/
/// dragonfly code paths stay exercised.
pub struct Scenario {
    pub name: &'static str,
    pub topology: &'static str,
    graph: fn() -> CsrGraph,
}

impl Scenario {
    pub fn graph(&self) -> CsrGraph {
        (self.graph)()
    }

    pub fn machine(&self) -> Machine {
        Machine::parse_spec(self.topology).expect("preset topology spec parses")
    }
}

/// Torus / fat-tree / dragonfly scenario presets: halo-exchange-style
/// task graphs onto the matching machine shapes.
pub fn scenario_presets() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "torus-halo",
            topology: "torus:4x4x4",
            graph: || gen::torus3d(16, 16, 8),
        },
        Scenario {
            name: "fattree-stencil",
            topology: "fattree:3:2,4,4/1,5,20",
            graph: || gen::stencil9(48, 48, 1),
        },
        Scenario {
            name: "dragonfly-rgg",
            topology: "dragonfly:4:4:2/1,2,5",
            graph: || gen::rgg(4_000, gen::rgg_paper_radius(4_000) * 1.2, 9),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::smoke_suite;

    #[test]
    fn matrix_runs_and_emits_csv() {
        let engine = Engine::new(crate::engine::EngineConfig { threads: 1, ..Default::default() });
        let specs: Vec<_> = smoke_suite().into_iter().take(1).collect();
        let hs = vec![Machine::hier("2:2", "1:10").unwrap()];
        let recs = run_matrix(&engine, &[Algorithm::GpuIm, Algorithm::SharedMapF], &specs, &hs, &[1], 0.03);
        assert_eq!(recs.len(), 2);
        for r in &recs {
            assert!(r.comm_cost > 0.0);
            assert!(r.to_csv().split(',').count() == ExpRecord::csv_header().split(',').count());
        }
    }

    #[test]
    fn matrix_submits_through_the_job_queue_and_keeps_order() {
        // Two engine workers + a tiny queue: submission must interleave
        // with execution (blocking on space) and records must come back
        // in matrix order even when cells finish out of order.
        let engine = Engine::new(crate::engine::EngineConfig {
            threads: 1,
            workers: 2,
            queue_cap: 2,
            ..Default::default()
        });
        let specs: Vec<_> = smoke_suite().into_iter().take(1).collect();
        let hs = vec![Machine::hier("2:2", "1:10").unwrap(), Machine::hier("4", "1").unwrap()];
        let recs = run_matrix(
            &engine,
            &[Algorithm::SharedMapF, Algorithm::GpuIm],
            &specs,
            &hs,
            &[1, 2],
            0.03,
        );
        assert_eq!(recs.len(), 4);
        let algos: Vec<&str> = recs.iter().map(|r| r.algorithm.name()).collect();
        assert_eq!(algos, vec!["sharedmap-f", "gpu-im", "sharedmap-f", "gpu-im"]);
        assert!(recs.iter().all(|r| r.comm_cost > 0.0 && r.seeds == 2));
    }

    #[test]
    fn env_defaults() {
        // (Do not set the env vars here: tests run in one process.)
        let seeds = seeds_from_env(&[1, 2, 3]);
        assert!(!seeds.is_empty());
        let hs = machines_from_env();
        assert!(!hs.is_empty());
        assert!(hs.iter().all(|h| h.k() % 32 == 0));
    }

    #[test]
    fn scenario_presets_are_well_formed() {
        for sc in scenario_presets() {
            let m = sc.machine();
            let g = sc.graph();
            assert!(m.k() > 1, "{}", sc.name);
            assert!(g.n() > m.k() * 8, "{}: graph too small for its machine", sc.name);
        }
    }
}
