//! Performance profiles (Dolan & Moré 2002) — the quality plots of
//! Figures 1 and 2.
//!
//! For algorithms `A` and instances `I` with qualities `q_A(I)` (lower is
//! better), the profile of `A` maps `τ ≥ 1` to the fraction of instances
//! with `q_A(I) ≤ τ · Best(I)`.

use std::collections::BTreeMap;

/// Quality matrix: `algorithms × instances` (lower is better).
pub struct ProfileInput {
    pub algorithm_names: Vec<String>,
    /// `quality[a][i]` for algorithm `a` on instance `i`.
    pub quality: Vec<Vec<f64>>,
}

/// A computed performance profile.
pub struct PerformanceProfile {
    pub algorithm_names: Vec<String>,
    pub taus: Vec<f64>,
    /// `fraction[a][t]`: share of instances solved within `taus[t] · best`.
    pub fraction: Vec<Vec<f64>>,
}

impl ProfileInput {
    /// Compute the profile over a log-spaced τ grid.
    pub fn compute(&self, taus: &[f64]) -> PerformanceProfile {
        let n_inst = self.quality.first().map(|q| q.len()).unwrap_or(0);
        assert!(self.quality.iter().all(|q| q.len() == n_inst), "ragged quality matrix");
        let mut best = vec![f64::INFINITY; n_inst];
        for q in &self.quality {
            for (i, &v) in q.iter().enumerate() {
                best[i] = best[i].min(v);
            }
        }
        let fraction = self
            .quality
            .iter()
            .map(|q| {
                taus.iter()
                    .map(|&tau| {
                        let ok = q
                            .iter()
                            .enumerate()
                            .filter(|&(i, &v)| v <= tau * best[i] + 1e-12)
                            .count();
                        ok as f64 / n_inst.max(1) as f64
                    })
                    .collect()
            })
            .collect();
        PerformanceProfile { algorithm_names: self.algorithm_names.clone(), taus: taus.to_vec(), fraction }
    }

    /// Fraction of instances on which each algorithm attains the best
    /// quality (the paper quotes these at τ = 1).
    pub fn best_fractions(&self) -> BTreeMap<String, f64> {
        let p = self.compute(&[1.0]);
        p.algorithm_names
            .iter()
            .cloned()
            .zip(p.fraction.iter().map(|f| f[0]))
            .collect()
    }

    /// Mean relative overhead above the best solution, in percent
    /// (the paper's "average additional cost over the best solution").
    pub fn mean_overhead_pct(&self) -> BTreeMap<String, f64> {
        let n_inst = self.quality.first().map(|q| q.len()).unwrap_or(0);
        let mut best = vec![f64::INFINITY; n_inst];
        for q in &self.quality {
            for (i, &v) in q.iter().enumerate() {
                best[i] = best[i].min(v);
            }
        }
        self.algorithm_names
            .iter()
            .cloned()
            .zip(self.quality.iter().map(|q| {
                let mean: f64 = q
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| v / best[i].max(1e-12) - 1.0)
                    .sum::<f64>()
                    / n_inst.max(1) as f64;
                100.0 * mean
            }))
            .collect()
    }
}

/// A log-spaced τ grid from 1 to `tau_max`.
pub fn tau_grid(tau_max: f64, points: usize) -> Vec<f64> {
    (0..points)
        .map(|i| (tau_max.ln() * i as f64 / (points - 1).max(1) as f64).exp())
        .collect()
}

/// Render a profile as a GitHub-markdown table (one row per τ).
pub fn profile_markdown(p: &PerformanceProfile) -> String {
    let mut s = String::new();
    s.push_str("| tau |");
    for name in &p.algorithm_names {
        s.push_str(&format!(" {name} |"));
    }
    s.push_str("\n|---|");
    for _ in &p.algorithm_names {
        s.push_str("---|");
    }
    s.push('\n');
    for (t, &tau) in p.taus.iter().enumerate() {
        s.push_str(&format!("| {tau:.3} |"));
        for f in &p.fraction {
            s.push_str(&format!(" {:.3} |", f[t]));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> ProfileInput {
        ProfileInput {
            algorithm_names: vec!["good".into(), "bad".into()],
            quality: vec![vec![1.0, 2.0, 3.0], vec![2.0, 2.0, 9.0]],
        }
    }

    #[test]
    fn profile_monotone_and_bounded() {
        let p = example().compute(&tau_grid(4.0, 16));
        for f in &p.fraction {
            for w in f.windows(2) {
                assert!(w[1] >= w[0] - 1e-12, "profile not monotone");
            }
            assert!(f.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn best_fractions_sum_ge_one() {
        let bf = example().best_fractions();
        assert!((bf["good"] - 1.0).abs() < 1e-12);
        assert!((bf["bad"] - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn overhead_pct() {
        let ov = example().mean_overhead_pct();
        assert!(ov["good"].abs() < 1e-9);
        // bad: (2/1-1 + 2/2-1 + 9/3-1)/3 = (1 + 0 + 2)/3 = 1 → 100%.
        assert!((ov["bad"] - 100.0).abs() < 1e-9);
    }

    #[test]
    fn markdown_renders() {
        let p = example().compute(&[1.0, 2.0]);
        let md = profile_markdown(&p);
        assert!(md.contains("| tau |"));
        assert!(md.lines().count() >= 4);
    }
}
