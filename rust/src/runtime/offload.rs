//! Device offload of the QAP swap search (the dense hot spot of two-phase
//! mapping, reformulated for matrix units — DESIGN.md §1).
//!
//! The L1 Pallas kernel `qap_step_k{K}` takes the block communication
//! matrix `W (K×K)`, the distance matrix `D (K×K)` and the one-hot PE
//! assignment `P (K×K)` and returns
//!
//! * `delta[x,y]` — the exact change of `J` if blocks `x` and `y` swap
//!   PEs (all `K²` swap candidates from two matmuls), and
//! * `j` — the current cost `Σ W ⊙ (P D Pᵀ)`.
//!
//! [`swap_refine_offload`] drives it: each sweep evaluates all swaps on
//! the device, then greedily applies non-conflicting improving swaps on
//! the host.

use super::{literal_matrix_f32, Runtime};
use crate::fault::{self, FaultPoint};
use crate::par::ledger;
use crate::partition::comm_cost_blocks;
use crate::topology::{DistanceOracle, Machine};
use crate::Block;
use anyhow::{bail, Result};

/// Padded kernel sizes compiled by `python/compile/aot.py`.
pub const QAP_KERNEL_SIZES: [usize; 3] = [32, 64, 256];

/// Pick the smallest compiled size ≥ k.
pub fn qap_kernel_size(k: usize) -> Result<usize> {
    QAP_KERNEL_SIZES
        .iter()
        .copied()
        .find(|&s| s >= k)
        .ok_or_else(|| anyhow::anyhow!("k={k} exceeds the largest compiled QAP kernel"))
}

/// One device evaluation: all-pairs swap deltas and the current cost.
pub struct QapStepOutput {
    /// `delta[x·k + y]` = J(after swapping x,y) − J(before); size k×k.
    pub delta: Vec<f64>,
    /// Current cost `J`.
    pub j: f64,
}

/// Run the `qap_step` kernel for a concrete (unpadded) `k`.
pub fn qap_step_device(
    rt: &Runtime,
    bmat: &[f64],
    k: usize,
    m: &Machine,
    sigma: &[Block],
) -> Result<QapStepOutput> {
    assert_eq!(bmat.len(), k * k);
    assert_eq!(sigma.len(), k);
    let kp = qap_kernel_size(k)?;
    let name = format!("qap_step_k{kp}");
    if !rt.available(&name) {
        bail!("artifact {name} missing — run `make artifacts`");
    }

    // Zero-pad W and D; zero rows in P for the padding region.
    let mut w = vec![0f64; kp * kp];
    let mut d = vec![0f64; kp * kp];
    let mut p = vec![0f64; kp * kp];
    for x in 0..k {
        for y in 0..k {
            w[x * kp + y] = bmat[x * k + y];
            d[x * kp + y] = m.distance(x as Block, y as Block);
        }
        p[x * kp + sigma[x] as usize] = 1.0;
    }

    let inputs = [
        literal_matrix_f32(&w, kp, kp)?,
        literal_matrix_f32(&d, kp, kp)?,
        literal_matrix_f32(&p, kp, kp)?,
    ];
    if fault::fire_global(FaultPoint::DeviceLaunch) {
        panic!("{}", fault::failure(FaultPoint::DeviceLaunch));
    }
    ledger::charge_device((3 * kp * kp * 4) as u64, (kp * kp * 4 + 4) as u64);
    let out = rt.execute(&name, &inputs)?;
    let (delta_l, j_l) = out.to_tuple2()?;
    let delta_f: Vec<f32> = delta_l.to_vec::<f32>()?;
    let j = j_l.to_vec::<f32>()?[0] as f64;

    let mut delta = vec![0f64; k * k];
    for x in 0..k {
        for y in 0..k {
            delta[x * k + y] = delta_f[x * kp + y] as f64;
        }
    }
    Ok(QapStepOutput { delta, j })
}

/// Device-accelerated pairwise-swap refinement, "device proposes, host
/// verifies": each sweep the kernel scores all `K²` swap candidates (the
/// O(K³) part); the host walks them best-first, re-verifying each delta
/// exactly in O(K) against the *current* assignment before applying —
/// swap deltas are not additive, so batch application without
/// verification can regress. Refines `sigma` in place; returns the total
/// improvement in `J`.
pub fn swap_refine_offload(
    rt: &Runtime,
    bmat: &[f64],
    k: usize,
    m: &Machine,
    sigma: &mut [Block],
    max_sweeps: usize,
) -> Result<f64> {
    // Host-side re-verification scans two oracle rows per candidate.
    let oracle = DistanceOracle::auto(m);
    let mut total = 0.0;
    for _ in 0..max_sweeps {
        let step = qap_step_device(rt, bmat, k, m, sigma)?;
        // Candidates with improving device scores, best first.
        let mut cand: Vec<(f64, usize, usize)> = Vec::new();
        for x in 0..k {
            for y in x + 1..k {
                let d = step.delta[x * k + y];
                if d < -1e-6 {
                    cand.push((d, x, y));
                }
            }
        }
        if cand.is_empty() {
            break;
        }
        cand.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut applied = 0usize;
        for (_, x, y) in cand {
            // Exact delta under the current (possibly already-swapped)
            // assignment.
            let d = crate::algo::qap::swap_delta(bmat, k, sigma, &oracle, x, y);
            if d < -1e-9 {
                sigma.swap(x, y);
                total -= d;
                applied += 1;
            }
        }
        if applied == 0 {
            break;
        }
    }
    Ok(total)
}

/// Greedy sweeps baked into one `qap_sweep` launch — must match
/// `python/compile/kernels/qap_batch.py::SWEEPS`.
pub const QAP_SWEEP_BATCH: usize = 16;

/// Fully batched pairwise-swap refinement, "device proposes, device
/// applies": the assignment stays on the device across
/// [`QAP_SWEEP_BATCH`] greedy sweeps per launch, so one round trip
/// replaces up to 16 score→download→verify→upload cycles of
/// [`swap_refine_offload`]. Each in-kernel sweep rescores all `K²`
/// candidates against the *current* assignment and applies only the
/// single best improving swap, so the non-additivity hazard the
/// per-sweep path re-verifies on the host cannot arise; the host checks
/// just the final assignment and falls back to the verify-per-swap path
/// in the (f32-rounding) corner case where the device result is not an
/// improvement. Refines `sigma` in place; returns the improvement in `J`.
pub fn swap_refine_batched(
    rt: &Runtime,
    bmat: &[f64],
    k: usize,
    m: &Machine,
    sigma: &mut [Block],
    max_sweeps: usize,
) -> Result<f64> {
    assert_eq!(bmat.len(), k * k);
    assert_eq!(sigma.len(), k);
    let kp = qap_kernel_size(k)?;
    let name = format!("qap_sweep_k{kp}");
    if !rt.available(&name) {
        // Older artifact set without the batched kernel: per-sweep path.
        return swap_refine_offload(rt, bmat, k, m, sigma, max_sweeps);
    }

    let oracle = DistanceOracle::auto(m);
    let j0 = comm_cost_blocks(bmat, k, sigma, &oracle);
    let original: Vec<Block> = sigma.to_vec();

    // W and D upload once; only sigma round-trips between launches.
    let mut w = vec![0f64; kp * kp];
    let mut d = vec![0f64; kp * kp];
    for x in 0..k {
        for y in 0..k {
            w[x * kp + y] = bmat[x * k + y];
            d[x * kp + y] = m.distance(x as Block, y as Block);
        }
    }
    let w_l = literal_matrix_f32(&w, kp, kp)?;
    let d_l = literal_matrix_f32(&d, kp, kp)?;
    let kk_l = xla::Literal::vec1(&[k as i64]);
    let mut cur: Vec<i32> =
        (0..kp).map(|x| if x < k { sigma[x] as i32 } else { -1 }).collect();

    for i in 0..max_sweeps.div_ceil(QAP_SWEEP_BATCH) {
        let sigma_l = xla::Literal::vec1(&cur);
        if fault::fire_global(FaultPoint::DeviceLaunch) {
            panic!("{}", fault::failure(FaultPoint::DeviceLaunch));
        }
        let h2d = if i == 0 { 2 * kp * kp * 4 + kp * 4 + 8 } else { kp * 4 };
        ledger::charge_device(h2d as u64, (kp * 4 + 4) as u64);
        let out = rt.execute_refs(&name, &[&w_l, &d_l, &sigma_l, &kk_l])?;
        let (sigma_out, _j) = out.to_tuple2()?;
        let next: Vec<i32> = sigma_out.to_vec::<i32>()?;
        let converged = next == cur;
        cur = next;
        if converged {
            break;
        }
    }

    for x in 0..k {
        sigma[x] = cur[x] as Block;
    }
    let j1 = comm_cost_blocks(bmat, k, sigma, &oracle);
    if j1 > j0 + 1e-9 {
        sigma.copy_from_slice(&original);
        return swap_refine_offload(rt, bmat, k, m, sigma, max_sweeps);
    }
    Ok(j0 - j1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::qap;
    use crate::rng::Rng;
    use crate::topology::Machine;

    fn runtime() -> Option<Runtime> {
        let rt = Runtime::new("artifacts").ok()?;
        if rt.available("qap_step_k32") {
            Some(rt)
        } else {
            eprintln!("skipping offload test: artifacts not built");
            None
        }
    }

    fn random_bmat(k: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut b = vec![0.0; k * k];
        for x in 0..k {
            for y in x + 1..k {
                let w = if rng.f64() < 0.5 { rng.below(20) as f64 } else { 0.0 };
                b[x * k + y] = w;
                b[y * k + x] = w;
            }
        }
        b
    }

    #[test]
    fn device_j_matches_host() {
        let Some(rt) = runtime() else { return };
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let k = h.k();
        let bmat = random_bmat(k, 1);
        let sigma: Vec<Block> = (0..k as Block).collect();
        let out = qap_step_device(&rt, &bmat, k, &h, &sigma).unwrap();
        let host = comm_cost_blocks(&bmat, k, &sigma, &h.oracle());
        assert!((out.j - host).abs() < 1e-3 * host.max(1.0), "device {} vs host {}", out.j, host);
    }

    #[test]
    fn device_deltas_match_host_swaps() {
        let Some(rt) = runtime() else { return };
        let h = Machine::hier("4:4", "1:10").unwrap();
        let k = h.k();
        let bmat = random_bmat(k, 2);
        let mut rng = Rng::new(3);
        let mut sigma: Vec<Block> = (0..k as Block).collect();
        rng.shuffle(&mut sigma);
        let out = qap_step_device(&rt, &bmat, k, &h, &sigma).unwrap();
        let j0 = comm_cost_blocks(&bmat, k, &sigma, &h.oracle());
        for x in 0..k {
            for y in x + 1..k {
                let mut s2 = sigma.clone();
                s2.swap(x, y);
                let expect = comm_cost_blocks(&bmat, k, &s2, &h.oracle()) - j0;
                let got = out.delta[x * k + y];
                assert!(
                    (got - expect).abs() < 1e-3 * expect.abs().max(1.0),
                    "swap ({x},{y}): device {got} vs host {expect}"
                );
            }
        }
    }

    #[test]
    fn offload_refine_matches_host_refine_quality() {
        let Some(rt) = runtime() else { return };
        let h = Machine::hier("2:4:2", "1:10:100").unwrap();
        let k = h.k();
        let bmat = random_bmat(k, 4);
        let mut rng = Rng::new(5);
        let mut sigma_dev: Vec<Block> = (0..k as Block).collect();
        rng.shuffle(&mut sigma_dev);
        let mut sigma_host = sigma_dev.clone();
        let j_init = comm_cost_blocks(&bmat, k, &sigma_dev, &h.oracle());
        swap_refine_offload(&rt, &bmat, k, &h, &mut sigma_dev, 30).unwrap();
        qap::swap_refine(&bmat, k, &mut sigma_host, &h.oracle(), 30);
        let j_dev = comm_cost_blocks(&bmat, k, &sigma_dev, &h.oracle());
        let j_host = comm_cost_blocks(&bmat, k, &sigma_host, &h.oracle());
        assert!(j_dev <= j_init);
        assert!(j_dev <= j_host * 1.15, "device {j_dev} vs host {j_host}");
        // Still a permutation.
        let mut seen = vec![false; k];
        for &pe in &sigma_dev {
            assert!(!seen[pe as usize]);
            seen[pe as usize] = true;
        }
    }

    #[test]
    fn batched_refine_improves_and_batches_launches() {
        let Some(rt) = runtime() else { return };
        if !rt.available("qap_sweep_k32") {
            eprintln!("skipping batched test: qap_sweep artifacts not built");
            return;
        }
        let h = Machine::hier("2:4:2", "1:10:100").unwrap();
        let k = h.k();
        let bmat = random_bmat(k, 4);
        let mut rng = Rng::new(5);
        let mut sigma: Vec<Block> = (0..k as Block).collect();
        rng.shuffle(&mut sigma);
        let j_init = comm_cost_blocks(&bmat, k, &sigma, &h.oracle());

        let before = ledger::device_snapshot();
        let improved = swap_refine_batched(&rt, &bmat, k, &h, &mut sigma, 32).unwrap();
        let delta = ledger::device_snapshot().since(before);

        let j_after = comm_cost_blocks(&bmat, k, &sigma, &h.oracle());
        assert!((j_init - j_after - improved).abs() < 1e-6);
        assert!(j_after <= j_init);
        // 32 requested sweeps batch into at most ceil(32/16) = 2 device
        // launches (plus none on the fallback path, which this run must
        // not take because the result improved).
        assert!(delta.device_launches <= 2, "launches {}", delta.device_launches);
        // Still a permutation.
        let mut seen = vec![false; k];
        for &pe in &sigma {
            assert!(!seen[pe as usize]);
            seen[pe as usize] = true;
        }
    }

    #[test]
    fn batched_refine_matches_per_sweep_quality() {
        let Some(rt) = runtime() else { return };
        if !rt.available("qap_sweep_k32") {
            return;
        }
        let h = Machine::hier("4:4", "1:10").unwrap();
        let k = h.k();
        let bmat = random_bmat(k, 7);
        let mut rng = Rng::new(9);
        let mut sigma_batch: Vec<Block> = (0..k as Block).collect();
        rng.shuffle(&mut sigma_batch);
        let mut sigma_sweep = sigma_batch.clone();
        swap_refine_batched(&rt, &bmat, k, &h, &mut sigma_batch, 32).unwrap();
        swap_refine_offload(&rt, &bmat, k, &h, &mut sigma_sweep, 32).unwrap();
        let j_batch = comm_cost_blocks(&bmat, k, &sigma_batch, &h.oracle());
        let j_sweep = comm_cost_blocks(&bmat, k, &sigma_sweep, &h.oracle());
        // Both greedy descents; neither dominates, but they must land in
        // the same quality regime.
        assert!(j_batch <= j_sweep * 1.15, "batched {j_batch} vs per-sweep {j_sweep}");
    }
}
