//! Device offload of the QAP swap search (the dense hot spot of two-phase
//! mapping, reformulated for matrix units — DESIGN.md §1).
//!
//! The L1 Pallas kernel `qap_step_k{K}` takes the block communication
//! matrix `W (K×K)`, the distance matrix `D (K×K)` and the one-hot PE
//! assignment `P (K×K)` and returns
//!
//! * `delta[x,y]` — the exact change of `J` if blocks `x` and `y` swap
//!   PEs (all `K²` swap candidates from two matmuls), and
//! * `j` — the current cost `Σ W ⊙ (P D Pᵀ)`.
//!
//! [`swap_refine_offload`] drives it: each sweep evaluates all swaps on
//! the device, then greedily applies non-conflicting improving swaps on
//! the host.

use super::{literal_matrix_f32, Runtime};
use crate::topology::{DistanceOracle, Machine};
use crate::Block;
use anyhow::{bail, Result};

/// Padded kernel sizes compiled by `python/compile/aot.py`.
pub const QAP_KERNEL_SIZES: [usize; 3] = [32, 64, 256];

/// Pick the smallest compiled size ≥ k.
pub fn qap_kernel_size(k: usize) -> Result<usize> {
    QAP_KERNEL_SIZES
        .iter()
        .copied()
        .find(|&s| s >= k)
        .ok_or_else(|| anyhow::anyhow!("k={k} exceeds the largest compiled QAP kernel"))
}

/// One device evaluation: all-pairs swap deltas and the current cost.
pub struct QapStepOutput {
    /// `delta[x·k + y]` = J(after swapping x,y) − J(before); size k×k.
    pub delta: Vec<f64>,
    /// Current cost `J`.
    pub j: f64,
}

/// Run the `qap_step` kernel for a concrete (unpadded) `k`.
pub fn qap_step_device(
    rt: &Runtime,
    bmat: &[f64],
    k: usize,
    m: &Machine,
    sigma: &[Block],
) -> Result<QapStepOutput> {
    assert_eq!(bmat.len(), k * k);
    assert_eq!(sigma.len(), k);
    let kp = qap_kernel_size(k)?;
    let name = format!("qap_step_k{kp}");
    if !rt.available(&name) {
        bail!("artifact {name} missing — run `make artifacts`");
    }

    // Zero-pad W and D; zero rows in P for the padding region.
    let mut w = vec![0f64; kp * kp];
    let mut d = vec![0f64; kp * kp];
    let mut p = vec![0f64; kp * kp];
    for x in 0..k {
        for y in 0..k {
            w[x * kp + y] = bmat[x * k + y];
            d[x * kp + y] = m.distance(x as Block, y as Block);
        }
        p[x * kp + sigma[x] as usize] = 1.0;
    }

    let inputs = [
        literal_matrix_f32(&w, kp, kp)?,
        literal_matrix_f32(&d, kp, kp)?,
        literal_matrix_f32(&p, kp, kp)?,
    ];
    let out = rt.execute(&name, &inputs)?;
    let (delta_l, j_l) = out.to_tuple2()?;
    let delta_f: Vec<f32> = delta_l.to_vec::<f32>()?;
    let j = j_l.to_vec::<f32>()?[0] as f64;

    let mut delta = vec![0f64; k * k];
    for x in 0..k {
        for y in 0..k {
            delta[x * k + y] = delta_f[x * kp + y] as f64;
        }
    }
    Ok(QapStepOutput { delta, j })
}

/// Device-accelerated pairwise-swap refinement, "device proposes, host
/// verifies": each sweep the kernel scores all `K²` swap candidates (the
/// O(K³) part); the host walks them best-first, re-verifying each delta
/// exactly in O(K) against the *current* assignment before applying —
/// swap deltas are not additive, so batch application without
/// verification can regress. Refines `sigma` in place; returns the total
/// improvement in `J`.
pub fn swap_refine_offload(
    rt: &Runtime,
    bmat: &[f64],
    k: usize,
    m: &Machine,
    sigma: &mut [Block],
    max_sweeps: usize,
) -> Result<f64> {
    // Host-side re-verification scans two oracle rows per candidate.
    let oracle = DistanceOracle::auto(m);
    let mut total = 0.0;
    for _ in 0..max_sweeps {
        let step = qap_step_device(rt, bmat, k, m, sigma)?;
        // Candidates with improving device scores, best first.
        let mut cand: Vec<(f64, usize, usize)> = Vec::new();
        for x in 0..k {
            for y in x + 1..k {
                let d = step.delta[x * k + y];
                if d < -1e-6 {
                    cand.push((d, x, y));
                }
            }
        }
        if cand.is_empty() {
            break;
        }
        cand.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut applied = 0usize;
        for (_, x, y) in cand {
            // Exact delta under the current (possibly already-swapped)
            // assignment.
            let d = crate::algo::qap::swap_delta(bmat, k, sigma, &oracle, x, y);
            if d < -1e-9 {
                sigma.swap(x, y);
                total -= d;
                applied += 1;
            }
        }
        if applied == 0 {
            break;
        }
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::qap;
    use crate::partition::comm_cost_blocks;
    use crate::rng::Rng;
    use crate::topology::Machine;

    fn runtime() -> Option<Runtime> {
        let rt = Runtime::new("artifacts").ok()?;
        if rt.available("qap_step_k32") {
            Some(rt)
        } else {
            eprintln!("skipping offload test: artifacts not built");
            None
        }
    }

    fn random_bmat(k: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut b = vec![0.0; k * k];
        for x in 0..k {
            for y in x + 1..k {
                let w = if rng.f64() < 0.5 { rng.below(20) as f64 } else { 0.0 };
                b[x * k + y] = w;
                b[y * k + x] = w;
            }
        }
        b
    }

    #[test]
    fn device_j_matches_host() {
        let Some(rt) = runtime() else { return };
        let h = Machine::hier("2:2:2", "1:10:100").unwrap();
        let k = h.k();
        let bmat = random_bmat(k, 1);
        let sigma: Vec<Block> = (0..k as Block).collect();
        let out = qap_step_device(&rt, &bmat, k, &h, &sigma).unwrap();
        let host = comm_cost_blocks(&bmat, k, &sigma, &h.oracle());
        assert!((out.j - host).abs() < 1e-3 * host.max(1.0), "device {} vs host {}", out.j, host);
    }

    #[test]
    fn device_deltas_match_host_swaps() {
        let Some(rt) = runtime() else { return };
        let h = Machine::hier("4:4", "1:10").unwrap();
        let k = h.k();
        let bmat = random_bmat(k, 2);
        let mut rng = Rng::new(3);
        let mut sigma: Vec<Block> = (0..k as Block).collect();
        rng.shuffle(&mut sigma);
        let out = qap_step_device(&rt, &bmat, k, &h, &sigma).unwrap();
        let j0 = comm_cost_blocks(&bmat, k, &sigma, &h.oracle());
        for x in 0..k {
            for y in x + 1..k {
                let mut s2 = sigma.clone();
                s2.swap(x, y);
                let expect = comm_cost_blocks(&bmat, k, &s2, &h.oracle()) - j0;
                let got = out.delta[x * k + y];
                assert!(
                    (got - expect).abs() < 1e-3 * expect.abs().max(1.0),
                    "swap ({x},{y}): device {got} vs host {expect}"
                );
            }
        }
    }

    #[test]
    fn offload_refine_matches_host_refine_quality() {
        let Some(rt) = runtime() else { return };
        let h = Machine::hier("2:4:2", "1:10:100").unwrap();
        let k = h.k();
        let bmat = random_bmat(k, 4);
        let mut rng = Rng::new(5);
        let mut sigma_dev: Vec<Block> = (0..k as Block).collect();
        rng.shuffle(&mut sigma_dev);
        let mut sigma_host = sigma_dev.clone();
        let j_init = comm_cost_blocks(&bmat, k, &sigma_dev, &h.oracle());
        swap_refine_offload(&rt, &bmat, k, &h, &mut sigma_dev, 30).unwrap();
        qap::swap_refine(&bmat, k, &mut sigma_host, &h.oracle(), 30);
        let j_dev = comm_cost_blocks(&bmat, k, &sigma_dev, &h.oracle());
        let j_host = comm_cost_blocks(&bmat, k, &sigma_host, &h.oracle());
        assert!(j_dev <= j_init);
        assert!(j_dev <= j_host * 1.15, "device {j_dev} vs host {j_host}");
        // Still a permutation.
        let mut seen = vec![false; k];
        for &pe in &sigma_dev {
            assert!(!seen[pe as usize]);
            seen[pe as usize] = true;
        }
    }
}
