//! The real device execution path: device-resident graphs and batched
//! PJRT launches for the hot multilevel kernels.
//!
//! The CPU worker pool ([`crate::par`]) *models* the paper's GPU; this
//! module *is* the device path: the engine activates a thread-local
//! device session for jobs whose backend resolves to `device`, and the
//! multilevel kernels — preference matching ([`match_round`]),
//! CAS-contraction gather ([`contract_gather`]) and Jet candidate
//! selection ([`jet_round`]) — execute their whole superstep as **one**
//! AOT-compiled PJRT launch instead of one pool kernel per operation.
//!
//! ## The device graph store
//!
//! The session owns a bounded store of device-resident graphs: the
//! padded CSR-as-edge-list arrays (`eu`, `adj`, `ew`, `vw`) of each
//! graph are converted to device literals **once** and reused by every
//! kernel on every round, level, job and seed that touches the same
//! `Arc<CsrGraph>`. Entries are keyed by graph *identity*
//! (`Weak<CsrGraph>` + pointer equality), so the lifetime ties itself to
//! the engine's pinned-graph store and hierarchy cache: as long as a
//! session graph stays pinned (or a coarse level stays cached), repeat
//! jobs, seed sweeps and warm remaps never re-upload — only the small
//! per-round state (matings, partitions, scalars) crosses the bus, and
//! the `h2d_bytes` counter proves it. Dropped graphs age out via their
//! dead weak handles; the store is capped at [`STORE_CAP`] entries.
//!
//! ## Scoping and fallback
//!
//! Kernels receive plain `&CsrGraph`, so the pipelines anchor the owning
//! `Arc` with [`graph_scope`] (an RAII stack) and the wrappers match it
//! by pointer. Every wrapper returns `Option`: `None` — session
//! inactive, graph beyond the largest compiled class, artifact missing,
//! or a PJRT error (counted in [`fallback_events`]) — means "run the CPU
//! pool kernel instead", so a partially-offloaded solve is always
//! well-defined. Graphs are padded to compiled size classes
//! ([`GRAPH_CLASSES`]); the actual `n`/`m`/`k` travel as scalar operands.
//!
//! The [`crate::fault::FaultPoint::DeviceLaunch`] point fires here on
//! every launch (global plane), panicking like a pool kernel launch so
//! the engine's fence, retry and degradation chain (device → cpu backend
//! first) see exactly the failure mode a flaky accelerator would produce.

use super::Runtime;
use crate::fault::{self, FaultPoint};
use crate::graph::CsrGraph;
use crate::par::ledger;
use crate::{Block, EWeight, Vertex};
use anyhow::Result;
use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::{Arc, Weak};

/// Compiled padded graph classes `(n_pad, m_pad)` — must match
/// `python/compile/aot.py::GRAPH_SIZES` (with `m_pad = 8·n_pad`).
/// Graphs larger than the last class run on the CPU pool.
pub const GRAPH_CLASSES: [(usize, usize); 3] =
    [(1024, 8192), (4096, 32768), (16384, 131072)];

/// Dense-block class of the Jet device kernel; `k` beyond this stays on
/// the CPU pool (mirrors the dense-oracle cutoff idea, sized for VMEM).
pub const JET_K_MAX: usize = 256;

/// Max device-resident graphs retained per session.
pub const STORE_CAP: usize = 32;

/// Smallest compiled class holding `n` vertices and `m` directed edges.
pub fn graph_class(n: usize, m: usize) -> Option<(usize, usize)> {
    GRAPH_CLASSES.iter().copied().find(|&(np, mp)| n <= np && m <= mp)
}

/// One graph's device-resident representation: padded edge-list +
/// weight literals, uploaded once and shared by all kernels.
struct DeviceGraph {
    n: usize,
    m: usize,
    n_pad: usize,
    m_pad: usize,
    eu: xla::Literal,
    adj: xla::Literal,
    ew: xla::Literal,
    vw: xla::Literal,
}

impl DeviceGraph {
    fn build(g: &CsrGraph) -> Option<DeviceGraph> {
        let (n_pad, m_pad) = graph_class(g.n(), g.num_directed())?;
        let mut eu = vec![0i32; m_pad];
        let mut adj = vec![0i32; m_pad];
        let mut ew = vec![0f64; m_pad];
        for v in 0..g.n() {
            for e in g.xadj[v] as usize..g.xadj[v + 1] as usize {
                eu[e] = v as i32;
                adj[e] = g.adj[e] as i32;
                ew[e] = g.ew[e];
            }
        }
        // Padding weight 1 keeps the rating denominator finite; padded
        // vertices own no edges, so the value is never observed.
        let mut vw = vec![1.0f64; n_pad];
        for v in 0..g.n() {
            vw[v] = g.vw[v] as f64; // exact: vertex weights stay below 2^53
        }
        let dg = DeviceGraph {
            n: g.n(),
            m: g.num_directed(),
            n_pad,
            m_pad,
            eu: xla::Literal::vec1(&eu),
            adj: xla::Literal::vec1(&adj),
            ew: xla::Literal::vec1(&ew),
            vw: xla::Literal::vec1(&vw),
        };
        ledger::charge_h2d((m_pad * (4 + 4 + 8) + n_pad * 8) as u64);
        Some(dg)
    }
}

/// A thread's device session: the PJRT runtime plus the device graph
/// store and a one-slot distance-matrix cache for the Jet kernel. Owned
/// by a thread-local (one PJRT client per engine-worker thread, the same
/// model as the engine's per-process polish [`Runtime`]).
struct DeviceSession {
    rt: Runtime,
    dir: String,
    graphs: Vec<(Weak<CsrGraph>, Rc<DeviceGraph>)>,
    /// `(key, padded literal)` of the last Jet distance matrix uploaded;
    /// topology distances are fixed per machine, so one slot suffices.
    dmat: Option<(u64, xla::Literal)>,
    /// Are the three graph-kernel artifact families present? Probed once.
    kernels: bool,
}

thread_local! {
    static SESSION: RefCell<Option<DeviceSession>> = const { RefCell::new(None) };
    /// Activation depth: wrappers only offload while a [`DeviceGuard`]
    /// is alive, so `backend=cpu` jobs on the same thread never touch
    /// the device even though the session outlives the job.
    static ACTIVE: Cell<u32> = const { Cell::new(0) };
    /// Stack of anchored graph Arcs (see [`graph_scope`]).
    static SCOPE: RefCell<Vec<Arc<CsrGraph>>> = const { RefCell::new(Vec::new()) };
    /// Kernel-level device→cpu fallbacks (PJRT execution errors) on this
    /// thread; the engine folds the per-job delta into its metrics.
    static FALLBACK_EVENTS: Cell<u64> = const { Cell::new(0) };
    /// Did `PjRtClient` creation fail on this thread? Cached so a broken
    /// plugin costs one attempt, not one per job.
    static CLIENT_FAILED: Cell<bool> = const { Cell::new(false) };
}

/// RAII activation for the current job; created by [`activate`].
pub struct DeviceGuard(());

impl Drop for DeviceGuard {
    fn drop(&mut self) {
        ACTIVE.with(|a| a.set(a.get() - 1));
    }
}

/// Activate the device path on this thread for the lifetime of the
/// guard. Returns `None` when the PJRT client cannot be created (cached)
/// — the caller falls back to the CPU pool. Creating the session lazily
/// compiles nothing; executables compile on first use per artifact.
pub fn activate(artifacts_dir: &str) -> Option<DeviceGuard> {
    if CLIENT_FAILED.with(|c| c.get()) {
        return None;
    }
    let ok = SESSION.with(|s| {
        let mut s = s.borrow_mut();
        match s.as_ref() {
            Some(sess) if sess.dir == artifacts_dir => true,
            _ => match Runtime::new(artifacts_dir) {
                Ok(rt) => {
                    let kernels = ["match_round", "contract_gather", "jet_round"]
                        .iter()
                        .all(|k| rt.available(&format!("{k}_n{}", GRAPH_CLASSES[0].0)));
                    *s = Some(DeviceSession {
                        rt,
                        dir: artifacts_dir.to_string(),
                        graphs: Vec::new(),
                        dmat: None,
                        kernels,
                    });
                    true
                }
                Err(_) => {
                    CLIENT_FAILED.with(|c| c.set(true));
                    false
                }
            },
        }
    });
    if !ok {
        return None;
    }
    ACTIVE.with(|a| a.set(a.get() + 1));
    Some(DeviceGuard(()))
}

/// Is a device session active on this thread?
pub fn active() -> bool {
    ACTIVE.with(|a| a.get() > 0)
}

/// Are the graph-kernel artifacts present in the active session?
/// (`backend=auto` probes this; the per-kernel `available` checks still
/// gate each launch individually.)
pub fn graph_kernels_available() -> bool {
    active() && SESSION.with(|s| s.borrow().as_ref().is_some_and(|sess| sess.kernels))
}

/// Cumulative kernel-level device→cpu fallback events on this thread.
pub fn fallback_events() -> u64 {
    FALLBACK_EVENTS.with(|c| c.get())
}

/// RAII anchor for the `Arc` owning a graph; created by [`graph_scope`].
pub struct GraphScope(());

impl Drop for GraphScope {
    fn drop(&mut self) {
        SCOPE.with(|s| {
            s.borrow_mut().pop();
        });
    }
}

/// Anchor `g` so device kernels called with `&CsrGraph` below this point
/// can find (and cache against) its owning `Arc`. The multilevel
/// pipelines open one scope per hierarchy level; kernels on unanchored
/// graphs simply stay on the CPU pool.
#[must_use = "the anchor is popped when the guard drops"]
pub fn graph_scope(g: &Arc<CsrGraph>) -> GraphScope {
    SCOPE.with(|s| s.borrow_mut().push(g.clone()));
    GraphScope(())
}

/// Fire the per-launch fault point, account the transfer, execute.
fn launch(
    rt: &Runtime,
    name: &str,
    inputs: &[&xla::Literal],
    h2d_bytes: u64,
    d2h_bytes: u64,
) -> Result<xla::Literal> {
    if fault::fire_global(FaultPoint::DeviceLaunch) {
        panic!("{}", fault::failure(FaultPoint::DeviceLaunch));
    }
    ledger::charge_device(h2d_bytes, d2h_bytes);
    rt.execute_refs(name, inputs)
}

/// Run `f` with the session and the device-resident form of `g` (built
/// on first use), or `None` when the device path does not apply here:
/// inactive session, unanchored graph, graph beyond the compiled
/// classes, missing artifact, or (after `f` errors) a PJRT failure.
fn with_graph<R>(
    g: &CsrGraph,
    kernel: &str,
    f: impl FnOnce(&mut DeviceSession, &DeviceGraph) -> Result<R>,
) -> Option<R> {
    if !active() {
        return None;
    }
    let anchor = SCOPE.with(|s| {
        s.borrow()
            .iter()
            .rev()
            .find(|a| std::ptr::eq(Arc::as_ptr(a), g as *const CsrGraph))
            .cloned()
    })?;
    SESSION.with(|s| {
        let mut s = s.borrow_mut();
        let sess = s.as_mut()?;
        // Identity lookup; dead weaks age out, the oldest entry evicts.
        let mut found = None;
        sess.graphs.retain(|(w, dg)| match w.upgrade() {
            Some(live) => {
                if Arc::ptr_eq(&live, &anchor) {
                    found = Some(dg.clone());
                }
                true
            }
            None => false,
        });
        let dg = match found {
            Some(dg) => dg,
            None => {
                let dg = Rc::new(DeviceGraph::build(g)?);
                if sess.graphs.len() >= STORE_CAP {
                    sess.graphs.remove(0);
                }
                sess.graphs.push((Arc::downgrade(&anchor), dg.clone()));
                dg
            }
        };
        if !sess.rt.available(&format!("{kernel}_n{}", dg.n_pad)) {
            return None;
        }
        match f(sess, &dg) {
            Ok(r) => Some(r),
            Err(_) => {
                // A real PJRT failure: fall back to the pool kernel for
                // this superstep (inputs are re-read from host state
                // every round, so no device state is lost).
                FALLBACK_EVENTS.with(|c| c.set(c.get() + 1));
                None
            }
        }
    })
}

/// `UNMATCHED` on the host side (`coarsen::match_par`).
const UNMATCHED: Vertex = Vertex::MAX;

/// One preference-matching round as a single device launch: per-edge
/// ratings (bit-for-bit the host's quotient rating + seeded edge noise),
/// per-vertex best preference (max rating, ties to the smallest
/// neighbor) and the mutual handshake. Returns the new mating, or `None`
/// for "use the CPU pool kernels".
pub fn match_round(
    g: &CsrGraph,
    mate: &[Vertex],
    max_pair_weight: f64,
    seed: u64,
) -> Option<Vec<Vertex>> {
    with_graph(g, "match_round", |sess, dg| {
        let mut m32 = vec![-2i32; dg.n_pad]; // padded vertices never match
        for (v, &mv) in mate.iter().enumerate() {
            m32[v] = if mv == UNMATCHED { -1 } else { mv as i32 };
        }
        let mate_l = xla::Literal::vec1(&m32);
        let nm_l = xla::Literal::vec1(&[dg.n as i64, dg.m as i64]);
        let maxw_l = xla::Literal::vec1(&[max_pair_weight]);
        let seed_l = xla::Literal::vec1(&[seed]);
        let inputs = [&dg.eu, &dg.adj, &dg.ew, &dg.vw, &mate_l, &nm_l, &maxw_l, &seed_l];
        let name = format!("match_round_n{}", dg.n_pad);
        let out = launch(
            &sess.rt,
            &name,
            &inputs,
            (dg.n_pad * 4 + 32) as u64,
            (dg.n_pad * 8) as u64,
        )?;
        let (_pref, mate_new) = out.to_tuple2()?;
        let m_new: Vec<i32> = mate_new.to_vec::<i32>()?;
        Ok(m_new[..dg.n]
            .iter()
            .map(|&x| if x < 0 { UNMATCHED } else { x as Vertex })
            .collect())
    })
}

/// The gather half of CAS contraction as one launch: both endpoints of
/// every directed edge mapped through the coarse map. Returns
/// `(cu, cv)` of length `m`, or `None` for the CPU path.
pub fn contract_gather(g: &CsrGraph, cmap: &[Vertex]) -> Option<(Vec<Vertex>, Vec<Vertex>)> {
    with_graph(g, "contract_gather", |sess, dg| {
        let mut c32 = vec![0i32; dg.n_pad];
        for (v, &cv) in cmap.iter().enumerate() {
            c32[v] = cv as i32;
        }
        let cmap_l = xla::Literal::vec1(&c32);
        let nm_l = xla::Literal::vec1(&[dg.n as i64, dg.m as i64]);
        let inputs = [&dg.eu, &dg.adj, &cmap_l, &nm_l];
        let name = format!("contract_gather_n{}", dg.n_pad);
        let out = launch(
            &sess.rt,
            &name,
            &inputs,
            (dg.n_pad * 4 + 16) as u64,
            (dg.m_pad * 8) as u64,
        )?;
        let (cu_l, cv_l) = out.to_tuple2()?;
        let cu: Vec<i32> = cu_l.to_vec::<i32>()?;
        let cv: Vec<i32> = cv_l.to_vec::<i32>()?;
        Ok((
            cu[..dg.m].iter().map(|&x| x as Vertex).collect(),
            cv[..dg.m].iter().map(|&x| x as Vertex).collect(),
        ))
    })
}

/// Jet candidate selection for one LP superstep as a single launch:
/// dense per-vertex block connectivity × the distance matrix gives every
/// move's gain at once (`gain(v, from→b) = Σ_c conn(c)·(D[from,c] −
/// D[b,c])`). Returns per-vertex `(dest, gain)` — `dest[v] == -1` means
/// no candidate — or `None` for the CPU path. The caller applies the Jet
/// filter to `gain` (float tolerance documented in the parity tests: the
/// dense summation order differs from the conn-table scan). The padded
/// distance matrix is cached on device under `dmat_key`, so repeat
/// rounds re-upload nothing.
pub fn jet_round(
    g: &CsrGraph,
    part: &[Block],
    locked: &[i32],
    k: usize,
    dmat_key: u64,
    dmat: &[EWeight],
) -> Option<(Vec<i32>, Vec<f64>)> {
    if k > JET_K_MAX {
        return None;
    }
    debug_assert_eq!(dmat.len(), k * k);
    with_graph(g, "jet_round", |sess, dg| {
        if sess.dmat.as_ref().map(|(key, _)| *key) != Some(dmat_key) {
            let mut padded = vec![0f64; JET_K_MAX * JET_K_MAX];
            for a in 0..k {
                padded[a * JET_K_MAX..a * JET_K_MAX + k]
                    .copy_from_slice(&dmat[a * k..(a + 1) * k]);
            }
            let lit = xla::Literal::vec1(&padded)
                .reshape(&[JET_K_MAX as i64, JET_K_MAX as i64])?;
            ledger::charge_h2d((JET_K_MAX * JET_K_MAX * 8) as u64);
            sess.dmat = Some((dmat_key, lit));
        }
        let mut p32 = vec![0i32; dg.n_pad];
        for (v, &b) in part.iter().enumerate() {
            p32[v] = b as i32;
        }
        let mut l32 = vec![1i32; dg.n_pad]; // padded vertices stay locked
        l32[..dg.n].copy_from_slice(&locked[..dg.n]);
        let part_l = xla::Literal::vec1(&p32);
        let locked_l = xla::Literal::vec1(&l32);
        let nmk_l = xla::Literal::vec1(&[dg.n as i64, dg.m as i64, k as i64]);
        let (_, dmat_l) = sess.dmat.as_ref().expect("dmat cached above");
        let inputs = [&dg.eu, &dg.adj, &dg.ew, &part_l, &locked_l, dmat_l, &nmk_l];
        let name = format!("jet_round_n{}", dg.n_pad);
        let out = launch(
            &sess.rt,
            &name,
            &inputs,
            (dg.n_pad * 8 + 24) as u64,
            (dg.n_pad * 12) as u64,
        )?;
        let (dest_l, gain_l) = out.to_tuple2()?;
        let dest: Vec<i32> = dest_l.to_vec::<i32>()?;
        let gain: Vec<f64> = gain_l.to_vec::<f64>()?;
        Ok((dest[..dg.n].to_vec(), gain[..dg.n].to_vec()))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn graph_classes_cover_and_reject() {
        assert_eq!(graph_class(10, 50), Some((1024, 8192)));
        assert_eq!(graph_class(1024, 8192), Some((1024, 8192)));
        assert_eq!(graph_class(1025, 10), Some((4096, 32768)));
        // Dense small graph overflows the edge budget of its n-class.
        assert_eq!(graph_class(1000, 10_000), Some((4096, 32768)));
        assert_eq!(graph_class(20_000, 10), None);
        assert_eq!(graph_class(16384, 131_073), None);
    }

    #[test]
    fn wrappers_are_none_without_activation() {
        let g = Arc::new(gen::grid2d(8, 8, false));
        let _scope = graph_scope(&g);
        assert!(!active());
        assert!(match_round(&g, &vec![UNMATCHED; g.n()], 1e18, 1).is_none());
        assert!(contract_gather(&g, &vec![0; g.n()]).is_none());
        assert!(jet_round(&g, &vec![0; g.n()], &vec![0; g.n()], 4, 1, &vec![0.0; 16]).is_none());
    }

    #[test]
    fn activation_guard_restores_inactive_state() {
        // Whether or not the PJRT plugin can come up here, activate()
        // must not panic and the guard must restore the inactive state.
        assert!(!active());
        if let Some(guard) = activate("artifacts") {
            assert!(active());
            drop(guard);
        }
        assert!(!active());
    }

    #[test]
    fn unanchored_graphs_stay_on_cpu() {
        let Some(_guard) = activate("artifacts") else { return };
        let g = Arc::new(gen::grid2d(8, 8, false));
        // No graph_scope: the wrapper cannot see the Arc, so it must
        // decline even with an active session.
        assert!(match_round(&g, &vec![UNMATCHED; g.n()], 1e18, 1).is_none());
    }
}
