//! PJRT runtime: load AOT-compiled JAX/Pallas kernels (HLO text produced
//! by `python/compile/aot.py`) and execute them from the Rust hot path.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! *only* consumer of its output. Interchange is HLO **text** — jax ≥ 0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids (see /opt/xla-example).

pub mod device;
pub mod offload;

use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A PJRT client plus a compiled-executable cache keyed by artifact name.
///
/// Not `Sync`: the coordinator owns one `Runtime` per worker thread, which
/// matches the one-client-per-device model of PJRT.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Platform string of the underlying PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Does `name.hlo.txt` exist in the artifact directory?
    pub fn available(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Load (or fetch from cache) the executable for artifact `name`.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!("artifact {} not found — run `make artifacts` first", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile artifact {name}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on literal inputs; returns the raw output
    /// literal (callers unwrap the tuple arity they expect).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute artifact {name}"))?;
        Ok(result[0][0].to_literal_sync()?)
    }

    /// Like [`Self::execute`], but borrowing the input literals — the
    /// device session ([`device`]) launches against literals cached in
    /// its graph store, which must not be moved or copied per launch.
    pub fn execute_refs(&self, name: &str, inputs: &[&xla::Literal]) -> Result<xla::Literal> {
        let exe = self.load(name)?;
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("execute artifact {name}"))?;
        Ok(result[0][0].to_literal_sync()?)
    }
}

/// Build an `f32` matrix literal from an `f64` slice (row-major `r × c`).
pub fn literal_matrix_f32(data: &[f64], r: usize, c: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), r * c);
    let f: Vec<f32> = data.iter().map(|&x| x as f32).collect();
    Ok(xla::Literal::vec1(&f).reshape(&[r as i64, c as i64])?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root.
        PathBuf::from("artifacts")
    }

    #[test]
    fn client_comes_up() {
        let rt = Runtime::new(artifacts_dir()).unwrap();
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let rt = Runtime::new(artifacts_dir()).unwrap();
        let err = match rt.load("definitely_not_an_artifact") {
            Err(e) => e,
            Ok(_) => panic!("expected load failure"),
        };
        assert!(format!("{err}").contains("make artifacts"));
    }

    #[test]
    fn literal_roundtrip() {
        let l = literal_matrix_f32(&[1.0, 2.0, 3.0, 4.0], 2, 2).unwrap();
        let v = l.to_vec::<f32>().unwrap();
        assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
