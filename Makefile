# Repo-level build helpers. The rust crate builds with plain cargo (from
# rust/); this Makefile only wraps the cross-language steps.

.PHONY: artifacts test bench-offload

# AOT-compile the JAX/Pallas kernels to the HLO artifacts the PJRT
# runtime loads (rust/artifacts/*.hlo.txt): the QAP polish kernels and
# the per-class graph kernels (match_round / contract_gather /
# jet_round). Needs jax[cpu] in the active Python environment.
artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts

test:
	cd rust && cargo test --release

# Per-phase CPU-vs-device crossover (writes rust/BENCH_offload.json).
bench-offload: artifacts
	cd rust && cargo bench --bench offload
